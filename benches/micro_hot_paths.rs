//! Micro-benchmarks of every hot path — the §Perf profiling harness.
//!
//! Run with `cargo bench --bench micro_hot_paths`.  Reports per-op costs
//! for: CameoSketch vs CubeSketch updates, batched delta computation,
//! hypertree vs gutter ingestion, multi-producer session ingest
//! (`ingest_producers_{1,2,4}`), sketch-delta merge, work-queue
//! handoff, lockstep vs pipelined remote transport under injected
//! latency, Borůvka queries, query latency idle vs under sustained
//! never-idle ingest (`query_latency_idle` vs
//! `query_latency_under_load_p{1,4}` — the epoch cut barrier's win),
//! multi-tenant fabric ingest (`ingest_tenants_{1,4,16}` — N logical
//! graphs over one shared distributor pool) and cross-tenant query
//! isolation (`query_under_hot_neighbor` — an idle tenant's query
//! while a neighbor tenant churns), GreedyCC ops, adjacency-matrix
//! bit flips, and RAM bandwidth — everything EXPERIMENTS.md §Perf
//! tracks.

use std::sync::Arc;

use landscape::baseline::AdjacencyMatrix;
use landscape::benchkit::{bench, fmt_rate, BenchArgs, Stats, Table};
use landscape::coordinator::work_queue::WorkQueue;
use landscape::hypertree::{BatchSink, Hypertree, HypertreeConfig, VertexBatch};
use landscape::metrics::Metrics;
use landscape::sketch::params::{encode_edge, SketchParams};
use landscape::sketch::seeds::SketchSeeds;
use landscape::sketch::{CameoSketch, CubeSketch, ShardSpec, SketchStore};
use landscape::stream::update::Update;
use landscape::util::rng::Xoshiro256;

struct NullSink;
impl BatchSink for NullSink {
    fn full_batch(&self, _shard: usize, _b: VertexBatch) {}
    fn local_batch(&self, _shard: usize, _v: u32, _o: &[u32]) {}
}

/// The seed design's merge target: one flat allocation behind a single
/// global mutex — the baseline the sharded store is measured against.
struct MutexStore {
    words_per_vertex: usize,
    words: std::sync::Mutex<Vec<u64>>,
}

impl MutexStore {
    fn new(params: &SketchParams) -> Self {
        Self {
            words_per_vertex: params.words(),
            words: std::sync::Mutex::new(vec![0u64; params.v as usize * params.words()]),
        }
    }

    fn merge_delta(&self, u: u32, delta: &[u64]) {
        let mut words = self.words.lock().unwrap();
        let base = u as usize * self.words_per_vertex;
        for (i, &d) in delta.iter().enumerate() {
            words[base + i] ^= d;
        }
    }
}

/// `bench` with warmup/iteration counts scaled by `--quick`.
fn sbench<F: FnMut()>(args: &BenchArgs, warmup: usize, iters: usize, f: F) -> Stats {
    let (w, i) = args.scale(warmup, iters);
    bench(w, i, f)
}

fn main() {
    let args = BenchArgs::parse();
    let v = 1u64 << 12;
    let params = SketchParams::for_vertices(v);
    let seeds = SketchSeeds::derive(&params, 42);
    let mut rng = Xoshiro256::new(9);
    let n = if args.quick { 20_000usize } else { 100_000usize };
    let edges: Vec<(u32, u32)> = (0..n)
        .map(|_| {
            let a = rng.next_below(v - 1) as u32;
            let b = a + 1 + rng.next_below(v - 1 - a as u64) as u32;
            (a, b)
        })
        .collect();
    let indices: Vec<u64> = edges.iter().map(|&(a, b)| encode_edge(a, b, v)).collect();

    let mut t = Table::new(
        "micro hot paths (V=2^12)",
        &["path", "ns_per_op", "rate"],
    );
    let mut row = |name: &str, secs_per_op: f64| {
        t.row(vec![
            name.to_string(),
            format!("{:.1}", secs_per_op * 1e9),
            fmt_rate(1.0 / secs_per_op),
        ]);
    };

    // sketch update kernels
    let mut buckets = vec![0u64; params.words()];
    let s = sbench(&args, 1, 5, || {
        for &idx in &indices {
            CameoSketch::apply_update(&mut buckets, &params, &seeds, idx);
        }
    });
    row("cameo_update", s.median / n as f64);

    let s = sbench(&args, 1, 3, || {
        for &idx in &indices[..n / 4] {
            CubeSketch::apply_update(&mut buckets, &params, &seeds, idx);
        }
    });
    row("cube_update", s.median / (n / 4) as f64);

    // batched delta (the worker hot path) — level-major loop (§Perf #1)
    let mut delta = vec![0u64; params.words()];
    let s = sbench(&args, 1, 5, || {
        CameoSketch::delta_of_batch_into(&mut delta, &params, &seeds, &indices);
    });
    row("cameo_delta_batch(level-major)", s.median / n as f64);

    // the pre-optimization variant: update-major via apply_update
    let s = sbench(&args, 1, 5, || {
        delta.fill(0);
        for &idx in &indices {
            CameoSketch::apply_update(&mut delta, &params, &seeds, idx);
        }
    });
    row("cameo_delta_batch(update-major)", s.median / n as f64);

    // merge (the main-node hot path)
    let store = SketchStore::new(params, 42);
    let s = sbench(&args, 1, 20, || {
        store.merge_delta(0, &delta);
    });
    row("delta_merge_per_word", s.median / params.words() as f64);

    // merge kernels head-to-head: the 8-way unrolled u64-chunk kernel
    // (`CameoSketch::merge`) vs its scalar reference
    // (`CameoSketch::merge_scalar`) across sketch sizes.  ns_per_op is
    // per merged word; BENCH_micro.json pins these rows so
    // `tools/bench_compare` flags kernel regressions (and the
    // scalar-vs-unrolled ratio documents the unrolling win).
    for vexp in [10u32, 14, 17] {
        let kv = 1u64 << vexp;
        let kparams = SketchParams::for_vertices(kv);
        let words = kparams.words();
        let mut krng = Xoshiro256::new(5 + vexp as u64);
        let mut acc: Vec<u64> = (0..words).map(|_| krng.next_u64()).collect();
        let kdelta: Vec<u64> = (0..words).map(|_| krng.next_u64()).collect();
        let reps = 64usize;
        let per_op = (reps * words) as f64;

        let s = sbench(&args, 1, 20, || {
            for _ in 0..reps {
                CameoSketch::merge_scalar(&mut acc, &kdelta);
            }
        });
        row(&format!("merge_scalar_v2^{vexp}"), s.median / per_op);

        let s = sbench(&args, 1, 20, || {
            for _ in 0..reps {
                CameoSketch::merge(&mut acc, &kdelta);
            }
        });
        row(&format!("merge_unrolled_v2^{vexp}"), s.median / per_op);
    }

    // merge path, multi-threaded: the sharded lock-free store (each
    // thread XOR-merges into its own shard, as the coordinator's
    // distributors do) vs the single-global-mutex design.  ns_per_op is
    // per merged word across ALL threads, so lower = higher aggregate
    // merge throughput.
    let merges_per_thread = 256usize;
    for threads in [1usize, 2, 4, 8] {
        let spec = ShardSpec::new(threads);
        let total_words = (threads * merges_per_thread * params.words()) as f64;

        let sharded = SketchStore::with_shards(params, 42, spec);
        let s = sbench(&args, 1, 5, || {
            std::thread::scope(|scope| {
                for t in 0..threads {
                    let sharded = &sharded;
                    let delta = &delta;
                    scope.spawn(move || {
                        let slots = spec.shard_len(t, v);
                        for i in 0..merges_per_thread {
                            if slots == 0 {
                                break; // shard owns no vertices at this V
                            }
                            sharded
                                .merge_delta_exclusive(spec.vertex_at(t, i % slots), delta);
                        }
                    });
                }
            });
        });
        row(&format!("merge_sharded_t{threads}"), s.median / total_words);

        let mutexed = MutexStore::new(&params);
        let s = sbench(&args, 1, 5, || {
            std::thread::scope(|scope| {
                for t in 0..threads {
                    let mutexed = &mutexed;
                    let delta = &delta;
                    scope.spawn(move || {
                        let slots = spec.shard_len(t, v);
                        for i in 0..merges_per_thread {
                            if slots == 0 {
                                break;
                            }
                            mutexed.merge_delta(spec.vertex_at(t, i % slots), delta);
                        }
                    });
                }
            });
        });
        row(&format!("merge_mutex_t{threads}"), s.median / total_words);
    }

    // hypertree vs gutter ingestion
    let metrics = Arc::new(Metrics::new());
    let tree = Arc::new(Hypertree::new(
        HypertreeConfig::for_vertices(v, params.words() * 2),
        metrics.clone(),
    ));
    let mut local = tree.local();
    let sink = NullSink;
    let s = sbench(&args, 1, 5, || {
        for &(a, b) in &edges {
            local.insert(a, b, &sink);
            local.insert(b, a, &sink);
        }
        local.flush(&sink);
    });
    row("hypertree_insert(x2)", s.median / n as f64);

    let gutter = landscape::gutter::GutterBuffer::new(
        v,
        params.words() * 2,
        ShardSpec::new(64),
        metrics,
    );
    let s = sbench(&args, 1, 5, || {
        for &(a, b) in &edges {
            gutter.insert(a, b, &sink);
            gutter.insert(b, a, &sink);
        }
    });
    row("gutter_insert(x2)", s.median / n as f64);

    // multi-producer session ingest (the API redesign's headline): the
    // same 200k-update stream through 1/2/4 concurrent IngestHandles at
    // V=2^14.  ns_per_op is per update end-to-end (handle create →
    // ingest on all producers → publish → flush barrier), so the rows
    // track how ingest rate scales with producer count until the shard
    // queues saturate.
    {
        use landscape::Landscape;

        let pv = 1u64 << 14;
        let n_up = if args.quick { 40_000usize } else { 200_000usize };
        let mut prng = Xoshiro256::new(77);
        let ups: Vec<Update> = (0..n_up)
            .map(|_| {
                let a = prng.next_below(pv - 1) as u32;
                let b = a + 1 + prng.next_below(pv - 1 - a as u64) as u32;
                Update::insert(a, b)
            })
            .collect();
        for producers in [1usize, 2, 4] {
            let chunks: Vec<Vec<Update>> = (0..producers)
                .map(|p| ups.iter().copied().skip(p).step_by(producers).collect())
                .collect();
            let session = Landscape::builder()
                .vertices(pv)
                .distributor_threads(2)
                .greedycc(false) // isolate the front-end path
                .build()
                .unwrap();
            let s = sbench(&args, 1, 3, || {
                std::thread::scope(|scope| {
                    for chunk in &chunks {
                        let mut h = session.ingest_handle();
                        scope.spawn(move || {
                            for &u in chunk {
                                h.ingest(u);
                            }
                        });
                    }
                });
                session.flush();
            });
            row(
                &format!("ingest_producers_{producers}"),
                s.median / n_up as f64,
            );
        }
    }

    // hybrid vs sketch-only ingest across stream density (the hybrid
    // vertex tier's headline): erdos-style G(V, E) insert streams at
    // V=2^14 with expected degree d ≈ 4 / 32 / 256 (p·V = d), pushed
    // through a full session once with the tier off
    // (`hybrid_threshold(0)`: every vertex a sketch from birth) and
    // once with it on (threshold 8).  At d=4 nearly every vertex stays
    // in its exact tier — an update costs a short sorted-vec toggle
    // instead of levels×columns×rows of hashing — so the hybrid row
    // should win outright; by d=256 nearly everything is promoted and
    // the two rows converge.  ns_per_op is per update end-to-end
    // (handle create → ingest → publish → flush barrier).
    {
        use landscape::Landscape;
        use std::collections::HashSet;

        let hv = 1u64 << 14;
        let densities: &[u64] = if args.quick { &[4, 32] } else { &[4, 32, 256] };
        for &d in densities {
            // G(V, E) with E = dV/2 distinct uniform edges ⇒ expected
            // degree d, matching G(V, p) at p·V = d
            let target = (hv * d / 2) as usize;
            let mut hrng = Xoshiro256::new(1000 + d);
            let mut seen = HashSet::with_capacity(target);
            let mut hups: Vec<Update> = Vec::with_capacity(target);
            while hups.len() < target {
                let a = hrng.next_below(hv - 1) as u32;
                let b = a + 1 + hrng.next_below(hv - 1 - a as u64) as u32;
                if seen.insert((a, b)) {
                    hups.push(Update::insert(a, b));
                }
            }
            for (name, threshold) in [("sketch_only", 0u32), ("hybrid", 8)] {
                let session = Landscape::builder()
                    .vertices(hv)
                    .distributor_threads(2)
                    .greedycc(false) // isolate the representation cost
                    .hybrid_threshold(threshold)
                    .build()
                    .unwrap();
                let s = sbench(&args, 1, 3, || {
                    let mut h = session.ingest_handle();
                    for &u in &hups {
                        h.ingest(u);
                    }
                    h.flush();
                    session.flush();
                });
                row(&format!("ingest_{name}_d{d}"), s.median / target as f64);
            }
        }
    }

    // resident vs spill-mode session ingest (the storage tier's
    // headline): the same uniform insert stream through a fully
    // resident session and through spill sessions whose resident
    // budget holds only 25% / 50% of the sketch blocks, so ingest
    // additionally pays gutter buffering, block faults, evictions,
    // and WAL appends.  ns_per_op is per update end-to-end (handle
    // create → ingest → publish → flush barrier, which in spill mode
    // is also the durable cut).
    {
        use landscape::Landscape;

        for vexp in [14u32, 17] {
            let sv = 1u64 << vexp;
            let sparams = SketchParams::for_vertices(sv);
            let block_bytes = 8 + sparams.words() as u64 * 8;
            let n_up = if args.quick { 20_000usize } else { 100_000usize };
            let mut srng = Xoshiro256::new(300 + vexp as u64);
            let sups: Vec<Update> = (0..n_up)
                .map(|_| {
                    let a = srng.next_below(sv - 1) as u32;
                    let b = a + 1 + srng.next_below(sv - 1 - a as u64) as u32;
                    Update::insert(a, b)
                })
                .collect();

            let mut run = |name: String, budget_pct: Option<u64>| {
                let dir = std::env::temp_dir().join(format!(
                    "landscape-bench-spill-{}-{name}",
                    std::process::id()
                ));
                let _ = std::fs::remove_dir_all(&dir);
                let mut b = Landscape::builder()
                    .vertices(sv)
                    .alpha(1)
                    .distributor_threads(2)
                    .greedycc(false); // isolate the storage path
                if let Some(pct) = budget_pct {
                    b = b
                        .storage_dir(&dir)
                        .resident_budget_bytes(sv * block_bytes * pct / 100);
                }
                let session = b.build().unwrap();
                let s = sbench(&args, 1, 3, || {
                    let mut h = session.ingest_handle();
                    for &u in &sups {
                        h.ingest(u);
                    }
                    h.flush();
                    session.flush();
                });
                row(&name, s.median / n_up as f64);
                drop(session);
                let _ = std::fs::remove_dir_all(&dir);
            };
            run(format!("ingest_resident_v2^{vexp}"), None);
            run(format!("ingest_spill_budget25pct_v2^{vexp}"), Some(25));
            run(format!("ingest_spill_budget50pct_v2^{vexp}"), Some(50));
        }
    }

    // work-queue handoff
    let q: WorkQueue<u64> = WorkQueue::new(1024);
    let s = sbench(&args, 1, 10, || {
        for i in 0..512u64 {
            q.push(i).unwrap();
        }
        while q.try_pop().is_some() {}
    });
    row("workqueue_push_pop", s.median / 512.0);

    // remote transport: lockstep (one blocking round trip per batch) vs
    // pipelined (window of W batches in flight) over localhost with an
    // injected 500µs per-reply latency — the regime real remote workers
    // live in.  ns_per_op is per batch: lockstep pays one full latency
    // per batch, the pipelined rows shrink roughly with W.
    {
        use landscape::coordinator::work_queue::EpochBarrier;
        use landscape::worker::remote::{
            PipelinedRemote, RemoteWorker, ServeOptions, WorkerServer,
        };
        use landscape::worker::{PendingBatch, SubmitBackend, WorkerBackend};
        use std::time::Duration;

        let latency = Duration::from_micros(500);
        let server = WorkerServer::bind_with(
            "127.0.0.1:0",
            ServeOptions {
                reply_latency: latency,
                fail_after_batches: None,
            },
        )
        .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(4));

        let nbatches = 32u64;
        let batch_others: Vec<u32> = (1..65).collect();

        let lockstep = RemoteWorker::connect(&addr, params, 42, 1).unwrap();
        let mut out = Vec::new();
        let s = sbench(&args, 1, 3, || {
            for _ in 0..nbatches {
                out.clear();
                lockstep.process(0, &batch_others, &mut out).unwrap();
            }
        });
        row("remote_lockstep_lat500us", s.median / nbatches as f64);
        lockstep.shutdown();

        let tickets = EpochBarrier::new();
        for w in [1usize, 4, 16] {
            let mut p = PipelinedRemote::connect(&addr, params, 42, 1, w).unwrap();
            let mut token = 0u64;
            let mut comps = Vec::new();
            let s = sbench(&args, 1, 3, || {
                let mut done = 0u64;
                for _ in 0..nbatches {
                    token += 1;
                    p.submit(PendingBatch {
                        token,
                        ticket: tickets.register(),
                        vertex: 0,
                        others: batch_others.clone(),
                    })
                    .unwrap();
                    p.drain(&mut comps, false).unwrap();
                    done += comps.len() as u64;
                    comps.clear();
                }
                p.flush_submits().unwrap();
                while done < nbatches {
                    p.drain(&mut comps, true).unwrap();
                    done += comps.len() as u64;
                    comps.clear();
                }
            });
            row(
                &format!("remote_pipelined_w{w}_lat500us"),
                s.median / nbatches as f64,
            );
            p.finish().unwrap();
        }
        let _ = server_thread.join();
    }

    // adjacency-matrix bit flip (the §2.1 comparison)
    let mut m = AdjacencyMatrix::new(v);
    let ups: Vec<Update> = edges.iter().map(|&(a, b)| Update::insert(a, b)).collect();
    let s = sbench(&args, 1, 10, || {
        for u in &ups {
            m.apply(u);
        }
    });
    row("adj_matrix_bit_flip", s.median / n as f64);

    // Borůvka query on a freshly populated store (NOT the merge-bench
    // store, which holds junk deltas by now)
    let qstore = SketchStore::new(params, 43);
    for &idx in &indices[..20_000] {
        let (a, b) = landscape::sketch::params::decode_edge(idx, v);
        qstore.apply_local(a, idx);
        qstore.apply_local(b, idx);
    }
    let s = sbench(&args, 1, 3, || {
        let _ = landscape::connectivity::boruvka::boruvka_components(&qstore);
    });
    row("boruvka_query_total", s.median);

    // tiered query path: tier 2 (full Borůvka over all V) vs tier 1
    // (warm-started from the surviving forest, aggregating only
    // dirty-region vertices).  Graph: 64 disjoint paths; `d` of them
    // have one forest edge deleted, so the partial tier touches d/64 of
    // the vertices.  Latency is seconds per query.
    for vexp in [10u32, 12, 14] {
        let qv = 1u64 << vexp;
        let qparams = SketchParams::for_vertices(qv);
        let comp = 64u32;
        let span = (qv as u32) / comp;
        let mut forest: Vec<(u32, u32)> = Vec::new();
        for c in 0..comp {
            let base = c * span;
            for i in 0..span - 1 {
                forest.push((base + i, base + i + 1));
            }
        }
        let qstore = SketchStore::new(qparams, 70 + vexp as u64);
        for &(a, b) in &forest {
            let idx = encode_edge(a, b, qv);
            qstore.apply_local(a, idx);
            qstore.apply_local(b, idx);
        }

        let mut deleted = 0u32;
        let mut surviving = forest.clone();
        let mut delete_paths = |upto: u32, surviving: &mut Vec<(u32, u32)>| {
            while deleted < upto {
                let mid = deleted * span + span / 2;
                let idx = encode_edge(mid, mid + 1, qv);
                // XOR-cancel the edge out of the sketch and drop it from
                // the warm-start forest
                qstore.apply_local(mid, idx);
                qstore.apply_local(mid + 1, idx);
                surviving.retain(|&e| e != (mid, mid + 1));
                deleted += 1;
            }
        };

        // tier-2 baseline at the 1-dirty state (the acceptance
        // comparison: one forest-edge delete, full vs partial)
        delete_paths(1, &mut surviving);
        let s = sbench(&args, 1, 3, || {
            let _ = landscape::connectivity::boruvka::boruvka_components(&qstore);
        });
        row(&format!("query_full_v2^{vexp}"), s.median);

        for d in [1u32, 8, 64] {
            delete_paths(d, &mut surviving);
            let active: Vec<u32> = (0..d * span).collect();
            let s = sbench(&args, 1, 3, || {
                // the clones mirror the real partial tier's seed
                // construction cost (partial_seed rebuilds its DSU per
                // query), so the row is end-to-end honest
                let _ = landscape::connectivity::boruvka::boruvka_components_from(
                    &qstore,
                    landscape::connectivity::Dsu::from_edges(
                        qv as usize,
                        &surviving,
                    ),
                    surviving.clone(),
                    &active,
                );
            });
            row(&format!("query_partial_d{d}_v2^{vexp}"), s.median);
        }
    }

    // query latency vs the epoch cut barrier: a forced tier-2 query on
    // an idle session vs the same query while 1 / 4 producers stream at
    // full rate without ever pausing.  Under the retired idle-waiting
    // barrier the loaded rows could block unboundedly (the query waited
    // for a lull in the pipeline); with epoch cuts they track the idle
    // row plus only the work in flight at cut time.
    {
        use landscape::util::testkit::{churn_chord, cycle_graph};
        use landscape::Landscape;
        use std::sync::atomic::{AtomicBool, Ordering};

        let qv = 1u64 << 12;
        let span = 16u32;
        let ncycles = (qv as u32) / span;
        for producers in [0usize, 1, 4] {
            let session = Landscape::builder()
                .vertices(qv)
                .alpha(1)
                .distributor_threads(2)
                .greedycc(false) // isolate the cut + sketch-read path
                .build()
                .unwrap();
            // base graph: disjoint cycles, fully published before timing
            {
                let mut h = session.ingest_handle();
                for u in cycle_graph(ncycles, span) {
                    h.ingest(u);
                }
                h.flush();
            }
            session.flush();

            let stop = AtomicBool::new(false);
            let median = std::thread::scope(|scope| {
                for p in 0..producers {
                    let mut h = session.ingest_handle();
                    let stop = &stop;
                    // partition-invariant churn: toggle producer-disjoint
                    // chords inside the cycles, publishing every round so
                    // the shared pipeline is never idle
                    scope.spawn(move || {
                        let mut i = 0u32;
                        while !stop.load(Ordering::Acquire) {
                            let (x, y) = churn_chord((i % ncycles) * span, p, span);
                            h.ingest(Update::insert(x, y));
                            h.ingest(Update::delete(x, y));
                            h.flush();
                            i += 1;
                        }
                    });
                }
                let q = session.query_handle();
                let s = sbench(&args, 1, 5, || {
                    let _ = q.full_connectivity_query();
                });
                stop.store(true, Ordering::Release);
                s.median
            });
            let name = if producers == 0 {
                "query_latency_idle".to_string()
            } else {
                format!("query_latency_under_load_p{producers}")
            };
            row(&name, median);
        }
    }

    // multi-tenant fabric ingest (the serving layer's headline): the
    // same stream split across N tenants of ONE fabric, each tenant its
    // own logical graph with its own producer thread, all multiplexed
    // over the same two distributors.  ns_per_op is per update
    // end-to-end (handle create → ingest on all tenants → per-tenant
    // flush barrier), so the rows track how much sharing the pipeline
    // costs as tenant count grows.
    {
        use landscape::serve::{Fabric, FabricConfig, TenantConfig};

        let tv = 1u64 << 12;
        let n_up = if args.quick { 40_000usize } else { 200_000usize };
        let mut trng = Xoshiro256::new(88);
        let tups: Vec<Update> = (0..n_up)
            .map(|_| {
                let a = trng.next_below(tv - 1) as u32;
                let b = a + 1 + trng.next_below(tv - 1 - a as u64) as u32;
                Update::insert(a, b)
            })
            .collect();
        for tenants in [1usize, 4, 16] {
            let mut fc = FabricConfig::for_vertices(tv);
            fc.base.distributor_threads = 2;
            fc.base.use_greedycc = false; // isolate the shared-pipeline path
            let fabric = Fabric::spawn(fc).unwrap();
            let ids: Vec<_> = (0..tenants)
                .map(|i| {
                    fabric
                        .create_tenant(TenantConfig::named(format!("t{i}"), tv))
                        .unwrap()
                })
                .collect();
            let chunks: Vec<Vec<Update>> = (0..tenants)
                .map(|p| tups.iter().copied().skip(p).step_by(tenants).collect())
                .collect();
            let s = sbench(&args, 1, 3, || {
                std::thread::scope(|scope| {
                    for (id, chunk) in ids.iter().zip(&chunks) {
                        let mut h = fabric.ingest_handle(*id).unwrap();
                        scope.spawn(move || {
                            for &u in chunk {
                                h.ingest(u);
                            }
                        });
                    }
                });
                for id in &ids {
                    fabric.flush(*id).unwrap();
                }
            });
            row(&format!("ingest_tenants_{tenants}"), s.median / n_up as f64);
        }
    }

    // cross-tenant query isolation: a forced tier-2 query on an idle
    // tenant while a neighbor tenant of the SAME fabric churns at full
    // rate without pausing.  Because every tenant has its own epoch
    // barrier, the idle tenant's cut settles against its own (empty)
    // in-flight set — the row should track `query_latency_idle`, not
    // `query_latency_under_load_p1`.
    {
        use landscape::serve::{Fabric, FabricConfig, TenantConfig};
        use landscape::util::testkit::{churn_chord, cycle_graph};
        use std::sync::atomic::{AtomicBool, Ordering};

        let qv = 1u64 << 12;
        let span = 16u32;
        let ncycles = (qv as u32) / span;
        let mut fc = FabricConfig::for_vertices(qv);
        fc.base.alpha = 1;
        fc.base.distributor_threads = 2;
        fc.base.use_greedycc = false; // isolate the cut + sketch-read path
        let fabric = Fabric::spawn(fc).unwrap();
        let idle = fabric
            .create_tenant(TenantConfig::named("idle", qv))
            .unwrap();
        let hot = fabric.create_tenant(TenantConfig::named("hot", qv)).unwrap();
        {
            let mut h = fabric.ingest_handle(idle).unwrap();
            for u in cycle_graph(ncycles, span) {
                h.ingest(u);
            }
        }
        fabric.flush(idle).unwrap();

        let stop = AtomicBool::new(false);
        let median = std::thread::scope(|scope| {
            let mut h = fabric.ingest_handle(hot).unwrap();
            let stop_ref = &stop;
            // partition-invariant churn on the hot tenant, publishing
            // every round so ITS pipeline never goes idle
            scope.spawn(move || {
                let mut i = 0u32;
                while !stop_ref.load(Ordering::Acquire) {
                    let (x, y) = churn_chord((i % ncycles) * span, 0, span);
                    h.ingest(Update::insert(x, y));
                    h.ingest(Update::delete(x, y));
                    h.flush();
                    i += 1;
                }
            });
            let q = fabric.query_handle(idle).unwrap();
            let s = sbench(&args, 1, 5, || {
                let _ = q.full_connectivity_query();
            });
            stop.store(true, Ordering::Release);
            s.median
        });
        row("query_under_hot_neighbor", median);
    }

    // GreedyCC ops
    let mut g = landscape::connectivity::greedycc::GreedyCC::fresh(v);
    let s = sbench(&args, 1, 5, || {
        for &(a, b) in &edges {
            g.on_insert(a, b);
        }
    });
    row("greedycc_insert", s.median / n as f64);

    // RAM bandwidth reference
    let (seq, rnd) = landscape::analysis::rambw::measure_defaults();
    row("ram_seq_write_8B", 8.0 / (seq.gib_per_sec() * (1u64 << 30) as f64));
    row("ram_random_write_8B", 8.0 / (rnd.gib_per_sec() * (1u64 << 30) as f64));

    landscape::experiments::emit(&t, "micro_hot_paths");
    if let Some(path) = &args.json {
        // the bench-trajectory format: diff against the committed
        // BENCH_micro.json with `tools/bench_compare`
        t.emit_json(path);
    }
}
