"""L1 correctness: Pallas CameoSketch kernel vs the scalar numpy oracle.

The CORE correctness signal of the compile path: the vectorized
interpret-mode kernel must match ref.py bit-for-bit on every shape and
value pattern hypothesis throws at it.
"""

import pytest

jax = pytest.importorskip("jax", reason="jax not installed; kernel tests need it")

jax.config.update("jax_enable_x64", True)

pytest.importorskip("hypothesis", reason="hypothesis not installed")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import cameo, hashing, ref
from compile.params import SketchParams, encode_edge


def run_kernel(indices, graph_seed, params, batch=None):
    batch = batch or max(8, len(indices))
    padded = np.zeros((batch,), dtype=np.uint64)
    padded[: len(indices)] = np.asarray(indices, dtype=np.uint64)
    dseeds, cseeds = model.seeds_for(params, graph_seed)
    out = cameo.cameo_delta(
        jnp.asarray(padded),
        jnp.asarray(dseeds),
        jnp.asarray(cseeds),
        rows=params.rows,
    )
    return np.asarray(out)


class TestHashingMatchesRef:
    """jnp hashing vs the plain-int reference."""

    def test_splitmix64_known_values(self):
        xs = np.array([0, 1, 0xDEADBEEF, (1 << 64) - 1], dtype=np.uint64)
        got = np.asarray(hashing.splitmix64(jnp.asarray(xs)))
        want = np.array([ref.splitmix64(int(x)) for x in xs], dtype=np.uint64)
        np.testing.assert_array_equal(got, want)

    @given(st.integers(min_value=0, max_value=(1 << 64) - 1))
    @settings(max_examples=200, deadline=None)
    def test_splitmix64_matches_ref(self, x):
        assert int(hashing.splitmix64(x)) == ref.splitmix64(x)

    @given(
        st.integers(min_value=0, max_value=(1 << 64) - 1),
        st.integers(min_value=0, max_value=63),
        st.integers(min_value=0, max_value=7),
    )
    @settings(max_examples=100, deadline=None)
    def test_seed_derivation_matches_ref(self, gs, lvl, col):
        assert int(hashing.level_seed(gs, lvl)) == ref.level_seed(gs, lvl)
        assert int(hashing.depth_seed(gs, lvl, col)) == ref.depth_seed(gs, lvl, col)
        assert int(hashing.checksum_seed(gs, lvl)) == ref.checksum_seed(gs, lvl)

    @given(
        st.integers(min_value=0, max_value=(1 << 64) - 1),
        st.sampled_from([8, 16, 22, 40]),
    )
    @settings(max_examples=200, deadline=None)
    def test_bucket_depth_matches_ref(self, h, rows):
        got = int(hashing.bucket_depth(jnp.uint64(h), rows))
        assert got == ref.bucket_depth(h, rows)

    def test_depth_distribution_geometric(self):
        """P[depth=1] should be ~1/2, P[depth=2] ~1/4 ..."""
        rows = 22
        n = 20000
        hs = np.asarray(
            hashing.splitmix64(jnp.arange(n, dtype=jnp.uint64))
        )
        depths = np.asarray(hashing.bucket_depth(jnp.asarray(hs), rows))
        frac1 = np.mean(depths == 1)
        frac2 = np.mean(depths == 2)
        assert abs(frac1 - 0.5) < 0.02
        assert abs(frac2 - 0.25) < 0.02


class TestKernelVsOracle:
    def test_small_fixed_batch(self):
        v = 64
        params = SketchParams.for_vertices(v)
        edges = [(0, 1), (0, 2), (1, 2), (5, 9), (62, 63), (0, 63)]
        idx = [encode_edge(a, b, v) for a, b in edges]
        got = run_kernel(idx, 1234567, params)
        want = ref.cameo_delta_ref(idx, 1234567, params.levels, params.columns, params.rows)
        np.testing.assert_array_equal(got, want)

    def test_empty_batch_is_zero(self):
        params = SketchParams.for_vertices(32)
        got = run_kernel([], 99, params, batch=16)
        assert not got.any()

    def test_padding_is_ignored(self):
        v = 32
        params = SketchParams.for_vertices(v)
        idx = [encode_edge(1, 2, v), encode_edge(3, 4, v)]
        small = run_kernel(idx, 7, params, batch=8)
        large = run_kernel(idx, 7, params, batch=64)
        np.testing.assert_array_equal(small, large)

    @given(
        st.integers(min_value=4, max_value=128),
        st.integers(min_value=0, max_value=(1 << 32) - 1),
        st.data(),
    )
    @settings(max_examples=25, deadline=None)
    def test_random_batches_match_oracle(self, v, gs, data):
        params = SketchParams.for_vertices(v)
        n_edges = data.draw(st.integers(min_value=0, max_value=20))
        edges = data.draw(
            st.lists(
                st.tuples(
                    st.integers(min_value=0, max_value=v - 2),
                    st.integers(min_value=0, max_value=v - 1),
                ),
                min_size=n_edges,
                max_size=n_edges,
            )
        )
        idx = [encode_edge(a, b if b > a else a + 1, v) for a, b in edges]
        got = run_kernel(idx, gs, params)
        want = ref.cameo_delta_ref(idx, gs, params.levels, params.columns, params.rows)
        np.testing.assert_array_equal(got, want)

    @given(st.sampled_from([4, 16, 100, 257, 1 << 12]))
    @settings(max_examples=8, deadline=None)
    def test_shape_sweep(self, v):
        """Kernel output shape tracks params for odd and even V."""
        params = SketchParams.for_vertices(v)
        idx = [encode_edge(0, 1, v)]
        got = run_kernel(idx, 5, params)
        assert got.shape == (params.levels, params.columns, params.rows, 2)


class TestLinearity:
    """delta(A ++ B) == delta(A) ^ delta(B) — the property the whole
    distributed design rests on (sketch deltas merge by XOR)."""

    @given(st.integers(min_value=0, max_value=(1 << 32) - 1), st.data())
    @settings(max_examples=20, deadline=None)
    def test_delta_is_linear(self, gs, data):
        v = 64
        params = SketchParams.for_vertices(v)
        pool = [encode_edge(a, b, v) for a in range(6) for b in range(a + 1, 8)]
        a = data.draw(st.lists(st.sampled_from(pool), max_size=12))
        b = data.draw(st.lists(st.sampled_from(pool), max_size=12))
        da = run_kernel(a, gs, params, batch=16)
        db = run_kernel(b, gs, params, batch=16)
        dab = run_kernel(a + b, gs, params, batch=32)
        np.testing.assert_array_equal(da ^ db, dab)

    def test_insert_delete_cancels(self):
        """An edge inserted then deleted leaves the sketch untouched."""
        v = 64
        params = SketchParams.for_vertices(v)
        e = encode_edge(3, 9, v)
        d = run_kernel([e, e], 11, params, batch=8)
        assert not d.any()


class TestQueryRecovery:
    def test_single_edge_recovered(self):
        v = 64
        params = SketchParams.for_vertices(v)
        gs = 2024
        e = encode_edge(10, 20, v)
        delta = run_kernel([e], gs, params)
        cseed = ref.checksum_seed(gs, 0)
        got = ref.query_column(delta[0, 0], cseed)
        assert got == e

    def test_recovery_rate_on_many_nonzeros(self):
        """With many nonzeros, >=2/3 of columns should stay good
        (Lemma H.4's bound, measured empirically)."""
        v = 256
        params = SketchParams.for_vertices(v)
        gs = 77
        rng = np.random.default_rng(1)
        edges = set()
        while len(edges) < 120:
            a, b = sorted(rng.integers(0, v, size=2).tolist())
            if a != b:
                edges.add((a, b))
        idx = [encode_edge(a, b, v) for a, b in edges]
        delta = run_kernel(idx, gs, params, batch=128)
        ok = 0
        total = 0
        for lvl in range(params.levels):
            cseed = ref.checksum_seed(gs, lvl)
            for c in range(params.columns):
                total += 1
                got = ref.query_column(delta[lvl, c], cseed)
                if got is not None and got in idx:
                    ok += 1
        assert ok / total > 0.60, f"recovery rate {ok}/{total}"
