"""Golden-fixture pinning: the exact bit patterns shared with the Rust
tests.  If these fail, the cross-language contract broke — Rust workers
and the AOT artifacts would produce incompatible sketches."""

import json
import os

import numpy as np
import pytest

from compile.kernels import ref
from compile.params import SketchParams

FIXTURES = os.path.join(os.path.dirname(__file__), "..", "..", "tests", "fixtures")


def load(name):
    path = os.path.join(FIXTURES, name)
    if not os.path.exists(path):
        pytest.skip(f"fixture {name} not generated yet (python gen_fixtures.py)")
    with open(path) as f:
        return json.load(f)


class TestHashGolden:
    def test_splitmix64_pinned(self):
        fx = load("hash_golden.json")
        for e in fx["splitmix64"]:
            assert ref.splitmix64(int(e["x"])) == int(e["splitmix64"])

    def test_seed_derivation_pinned(self):
        fx = load("hash_golden.json")
        for e in fx["seeds"]:
            gs, lvl, col = int(e["graph_seed"]), e["level"], e["column"]
            assert ref.level_seed(gs, lvl) == int(e["level_seed"])
            assert ref.depth_seed(gs, lvl, col) == int(e["depth_seed"])
            assert ref.checksum_seed(gs, lvl) == int(e["checksum_seed"])

    def test_depths_pinned(self):
        fx = load("hash_golden.json")
        for e in fx["depths"]:
            assert ref.bucket_depth(int(e["h"]), e["rows"]) == e["depth"]


class TestDeltaGolden:
    def test_delta_pinned(self):
        fx = load("delta_golden.json")
        params = SketchParams.for_vertices(fx["vertices"])
        assert (params.levels, params.columns, params.rows) == (
            fx["levels"],
            fx["columns"],
            fx["rows"],
        )
        idx = [int(i) for i in fx["indices"]]
        delta = ref.cameo_delta_ref(
            idx, int(fx["graph_seed"]), params.levels, params.columns, params.rows
        )
        flat = [str(int(x)) for x in np.asarray(delta).reshape(-1)]
        assert flat == fx["delta"]
