"""L2 tests: the sketch-delta model (shapes, chunking, seed derivation)
and the AOT lowering path."""

import pytest

jax = pytest.importorskip("jax", reason="jax not installed; model tests need it")

jax.config.update("jax_enable_x64", True)

pytest.importorskip("hypothesis", reason="hypothesis not installed")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref
from compile.params import (
    SketchParams,
    decode_edge,
    encode_edge,
    num_levels,
    num_rows,
)


class TestParams:
    def test_levels_grow_with_v(self):
        assert num_levels(2) <= num_levels(1 << 10) <= num_levels(1 << 17)

    def test_known_values(self):
        # ceil(log_{1.5} 2^13) = 23, rows = 26 + 6
        assert num_levels(1 << 13) == 23
        assert num_rows(1 << 13) == 32

    def test_sketch_bytes_polylog(self):
        """Sketch size must be O(log^3 V) per vertex — i.e. tiny compared
        to a dense adjacency row for large V (Claim 1.1)."""
        v = 1 << 16
        p = SketchParams.for_vertices(v)
        assert p.bytes < 64 * 1024  # ~ tens of KiB
        assert p.bytes * 8 < v * v // 4  # sketch << adjacency matrix

    @given(st.integers(min_value=2, max_value=1 << 20))
    @settings(max_examples=100, deadline=None)
    def test_params_positive(self, v):
        p = SketchParams.for_vertices(v)
        assert p.levels >= 1 and p.rows >= 8 and p.columns >= 2

    @given(st.data())
    @settings(max_examples=200, deadline=None)
    def test_edge_encode_decode_roundtrip(self, data):
        v = data.draw(st.integers(min_value=2, max_value=1 << 20))
        a = data.draw(st.integers(min_value=0, max_value=v - 2))
        b = data.draw(st.integers(min_value=a + 1, max_value=v - 1))
        idx = encode_edge(a, b, v)
        assert idx != 0
        assert decode_edge(idx, v) == (a, b)

    def test_encode_orientation_invariant(self):
        assert encode_edge(3, 7, 100) == encode_edge(7, 3, 100)


class TestSeeds:
    def test_seeds_match_ref(self):
        params = SketchParams.for_vertices(128)
        d, c = model.seeds_for(params, 42)
        for lvl in range(params.levels):
            assert int(c[lvl]) == ref.checksum_seed(42, lvl)
            for col in range(params.columns):
                assert int(d[lvl, col]) == ref.depth_seed(42, lvl, col)

    def test_seeds_differ_between_levels_and_columns(self):
        params = SketchParams.for_vertices(128)
        d, c = model.seeds_for(params, 42)
        assert len(set(d.reshape(-1).tolist())) == d.size
        assert len(set(c.tolist())) == c.size


class TestComputeDelta:
    def test_chunking_invariance(self):
        """compute_delta must give identical results for any batch size
        (the worker chunks batches into the compiled B)."""
        v = 64
        params = SketchParams.for_vertices(v)
        rng = np.random.default_rng(3)
        idx = [
            encode_edge(*sorted(rng.choice(v, size=2, replace=False).tolist()), v)
            for _ in range(50)
        ]
        d8 = model.compute_delta(idx, params, 9, batch=8)
        d16 = model.compute_delta(idx, params, 9, batch=16)
        d64 = model.compute_delta(idx, params, 9, batch=64)
        np.testing.assert_array_equal(d8, d16)
        np.testing.assert_array_equal(d16, d64)

    def test_matches_oracle(self):
        v = 32
        params = SketchParams.for_vertices(v)
        idx = [encode_edge(0, 1, v), encode_edge(2, 3, v), encode_edge(0, 1, v)]
        got = model.compute_delta(idx, params, 5, batch=4)
        want = ref.cameo_delta_ref(idx, 5, params.levels, params.columns, params.rows)
        np.testing.assert_array_equal(got, want)


class TestAotLowering:
    def test_hlo_text_emitted(self):
        params = SketchParams.for_vertices(64)
        text = aot.lower_config(params, batch=16)
        assert text.startswith("HloModule")
        assert "u64" in text
        # the xor-fold reduction must survive lowering
        assert "xor" in text

    def test_hlo_entry_shapes(self):
        params = SketchParams.for_vertices(64)
        text = aot.lower_config(params, batch=16)
        first = text.splitlines()[0]
        assert f"u64[16]" in first  # batch input
        assert (
            f"u64[{params.levels},{params.columns},{params.rows},2]" in first
        )  # delta output

    def test_artifact_shape_dedupe(self):
        """V values with identical (L,C,R) share one artifact."""
        p1 = SketchParams.for_vertices(1 << 13)
        p2 = SketchParams.for_vertices((1 << 13) - 1)
        assert (p1.levels, p1.rows) == (p2.levels, p2.rows)
