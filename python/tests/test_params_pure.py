"""Pure-python parameter-derivation tests (no jax, no numpy).

These always run — even in runner images without jax — so the python CI
job has real coverage instead of a fully-skipped suite, and the
cross-language shape contract (`rust/src/sketch/params.rs`) is pinned on
the python side too.
"""

from compile.params import (
    SketchParams,
    decode_edge,
    encode_edge,
    num_levels,
    num_rows,
)


class TestShapes:
    def test_known_values_match_rust(self):
        # pinned against rust/src/sketch/params.rs::known_values_match_python
        assert num_levels(1 << 13) == 23
        assert num_rows(1 << 13) == 32
        assert num_levels(1 << 17) == 30
        assert num_rows(1 << 17) == 40

    def test_levels_monotone(self):
        prev = 0
        for p in range(1, 22):
            lvl = num_levels(1 << p)
            assert lvl >= prev
            prev = lvl

    def test_words_accounting(self):
        p = SketchParams.for_vertices(64)
        assert p.words_per_level == p.columns * p.rows * 2
        assert p.words == p.levels * p.words_per_level
        assert p.bytes == p.words * 8


class TestEdgeEncoding:
    def test_roundtrip(self):
        v = 1 << 10
        for a, b in [(0, 1), (3, 700), (1022, 1023)]:
            idx = encode_edge(a, b, v)
            assert idx != 0
            assert decode_edge(idx, v) == (a, b)

    def test_orientation_invariant(self):
        assert encode_edge(3, 7, 100) == encode_edge(7, 3, 100)

    def test_zero_is_reserved_sentinel(self):
        # the smallest encodable edge never collides with padding
        assert encode_edge(0, 1, 16) == 2
