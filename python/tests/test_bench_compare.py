"""tools/bench_compare contract tests: the committed-trajectory diff.

The tool is the enforcement half of BENCH_micro.json — CI's bench-smoke
job runs it against a fresh `--json` bench run.  These tests pin the
exit-code contract with the committed baseline itself plus synthetic
current runs, so a tool regression can't silently turn the bench gate
into a no-op.
"""

import json
import os
import subprocess
import sys

import pytest

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
TOOL = os.path.join(REPO_ROOT, "tools", "bench_compare")
BASELINE = os.path.join(REPO_ROOT, "BENCH_micro.json")


def run_compare(*argv):
    return subprocess.run(
        [sys.executable, TOOL, *argv],
        capture_output=True,
        text=True,
        cwd=REPO_ROOT,
    )


@pytest.fixture()
def baseline_doc():
    with open(BASELINE, encoding="utf-8") as f:
        return json.load(f)


def write_doc(tmp_path, doc):
    p = tmp_path / "current.json"
    p.write_text(json.dumps(doc), encoding="utf-8")
    return str(p)


def test_baseline_pins_the_unrolling_win(baseline_doc):
    """The committed rows must show >= 1.5x scalar-vs-unrolled at V=2^14."""
    rows = {r["path"]: r for r in baseline_doc["rows"]}
    scalar = float(rows["merge_scalar_v2^14"]["ns_per_op"])
    unrolled = float(rows["merge_unrolled_v2^14"]["ns_per_op"])
    assert scalar / unrolled >= 1.5


def test_identical_run_passes():
    r = run_compare("--current", BASELINE)
    assert r.returncode == 0, r.stdout + r.stderr


def test_twenty_percent_regression_fails(tmp_path, baseline_doc):
    """A synthetic 20% ns_per_op regression must exit nonzero."""
    for row in baseline_doc["rows"]:
        if row["path"] == "merge_unrolled_v2^14":
            row["ns_per_op"] = str(float(row["ns_per_op"]) * 1.2)
    r = run_compare("--current", write_doc(tmp_path, baseline_doc))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "merge_unrolled_v2^14" in r.stdout


def test_regression_within_threshold_passes(tmp_path, baseline_doc):
    """The same 20% slip passes when the caller widens the tolerance."""
    for row in baseline_doc["rows"]:
        if row["path"] == "merge_unrolled_v2^14":
            row["ns_per_op"] = str(float(row["ns_per_op"]) * 1.2)
    r = run_compare(
        "--current", write_doc(tmp_path, baseline_doc), "--threshold", "0.25"
    )
    assert r.returncode == 0, r.stdout + r.stderr


def test_improvement_passes_and_is_reported(tmp_path, baseline_doc):
    for row in baseline_doc["rows"]:
        row["ns_per_op"] = str(float(row["ns_per_op"]) * 0.5)
    r = run_compare("--current", write_doc(tmp_path, baseline_doc))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "improvements:" in r.stdout


def test_unpinned_rows_never_fail(tmp_path, baseline_doc):
    """Rows only in the current run (new benches) are notes, not failures."""
    baseline_doc["rows"].append(
        {"path": "brand_new_bench", "ns_per_op": "999.0", "rate": "1.00 M/s"}
    )
    r = run_compare("--current", write_doc(tmp_path, baseline_doc))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "not pinned in baseline" in r.stdout


def test_missing_baseline_rows_never_fail(tmp_path, baseline_doc):
    """A quick-mode run that skipped rows must not fail the gate."""
    baseline_doc["rows"] = baseline_doc["rows"][:2]
    r = run_compare("--current", write_doc(tmp_path, baseline_doc))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "missing from current run" in r.stdout


def test_malformed_input_exits_2(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{not json", encoding="utf-8")
    r = run_compare("--current", str(p))
    assert r.returncode == 2


def test_update_rewrites_pinned_rows(tmp_path, baseline_doc):
    """--update refreshes pinned values in place, keeping provenance."""
    base_copy = tmp_path / "baseline.json"
    base_copy.write_text(json.dumps(baseline_doc), encoding="utf-8")
    current = json.loads(json.dumps(baseline_doc))
    for row in current["rows"]:
        if row["path"] == "merge_scalar_v2^14":
            row["ns_per_op"] = "0.9"
    r = run_compare(
        "--baseline", str(base_copy), "--current", write_doc(tmp_path, current), "--update"
    )
    assert r.returncode == 0, r.stdout + r.stderr
    updated = json.loads(base_copy.read_text(encoding="utf-8"))
    rows = {r["path"]: r for r in updated["rows"]}
    assert rows["merge_scalar_v2^14"]["ns_per_op"] == "0.9"
    assert "provenance" in updated
