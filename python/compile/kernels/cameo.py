"""CameoSketch batched-delta Pallas kernel (L1).

The compute hot-spot of the paper: turning a vertex-based batch of edge
updates into a *sketch delta* (paper §5.2).  For one sketch level the
work per update is: one checksum hash, C depth hashes, and four XORs per
column (deterministic row 0 + the geometric row), exactly the
CameoSketch update procedure of Fig. 12.

Kernel layout
  grid = (L,)  -- one program per sketch level; each level has its own
                  depth/checksum seeds, so levels are fully independent
                  and map cleanly onto a TPU grid.
  inputs   idx[B]            uint64  edge-vector indices, 0 = padding
           dseeds[L, C]      uint64  depth-hash seeds
           cseeds[L]         uint64  checksum-hash seeds
  output   delta[L, C, R, 2] uint64  (alpha, gamma) bucket deltas

TPU adaptation (DESIGN.md §Hardware-Adaptation): the per-level block
(B + C*R*2 words) is VMEM-resident; the bucket accumulation is a masked
XOR-reduce over the batch axis — VPU work, no MXU involvement, so the
roofline is memory-bound.  On CPU we run interpret=True (Mosaic
custom-calls are not executable on the CPU PJRT plugin).

The update is *linear*: delta(batch1 ++ batch2) = delta(batch1) XOR
delta(batch2).  Workers exploit this to chunk arbitrary batch sizes into
the fixed B compiled here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import hashing


def _xor_reduce(x, axis):
    """XOR-fold an array along ``axis`` (identity element 0)."""
    return jax.lax.reduce(x, jnp.uint64(0), jax.lax.bitwise_xor, (axis,))


def _cameo_level_kernel(idx_ref, dseed_ref, cseed_ref, out_ref, *, rows):
    """One grid step: the full delta of one sketch level."""
    idx = idx_ref[...]  # (B,)
    dseeds = dseed_ref[0, :]  # (C,)
    cseed = cseed_ref[0]  # scalar

    valid = idx != jnp.uint64(0)  # (B,)
    chk = hashing.checksum(cseed, idx)  # (B,)

    # Depth hash per (column, batch element); row choice is geometric.
    h = hashing.depth_hash(dseeds[:, None], idx[None, :])  # (C, B)
    depth = hashing.bucket_depth(h, rows)  # (C, B) int32

    # mask[c, r, b] — does update b touch bucket (c, r)?  Row 0 is the
    # deterministic bucket (hit by every valid update); row `depth` is the
    # geometric bucket.  This is the CameoSketch rule: exactly two bucket
    # writes per (update, column), vs CubeSketch's `depth` writes.
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (1, rows, 1), 1)  # (1,R,1)
    hit = (row_ids == depth[:, None, :]) | (row_ids == 0)  # (C,R,B)
    mask = hit & valid[None, None, :]

    zero = jnp.uint64(0)
    alpha = _xor_reduce(jnp.where(mask, idx[None, None, :], zero), 2)  # (C,R)
    gamma = _xor_reduce(jnp.where(mask, chk[None, None, :], zero), 2)  # (C,R)
    out_ref[0] = jnp.stack([alpha, gamma], axis=-1)  # (C,R,2)


def cameo_delta(idx, dseeds, cseeds, *, rows, interpret=True):
    """Compute the (L, C, R, 2) sketch delta of a padded batch.

    Args:
      idx:     (B,) uint64 edge-vector indices, 0-padded.
      dseeds:  (L, C) uint64 depth seeds.
      cseeds:  (L,) uint64 checksum seeds.
      rows:    R, bucket rows per column.
      interpret: keep True for CPU execution (see module docstring).
    """
    levels, columns = dseeds.shape
    batch = idx.shape[0]
    kernel = functools.partial(_cameo_level_kernel, rows=rows)
    return pl.pallas_call(
        kernel,
        grid=(levels,),
        in_specs=[
            pl.BlockSpec((batch,), lambda l: (0,)),
            pl.BlockSpec((1, columns), lambda l: (l, 0)),
            pl.BlockSpec((1,), lambda l: (l,)),
        ],
        out_specs=pl.BlockSpec((1, columns, rows, 2), lambda l: (l, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((levels, columns, rows, 2), jnp.uint64),
        interpret=interpret,
    )(idx, dseeds, cseeds)
