"""splitmix64-based sketch hashing — jnp implementation.

Bit-identical to ``rust/src/hashing/mod.rs``.  All randomness used by the
sketches derives from the splitmix64 finalizer applied to seed^input.  The
paper uses xxHash; any mixer of comparable quality preserves the sketch
guarantees (DESIGN.md "Substitutions"), and splitmix64 is trivial to keep
bit-identical across Rust and JAX.

Requires ``jax_enable_x64``.  Python ints passed through ``U64`` are
reduced mod 2^64 so plain-int call sites behave like wrapping u64 math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

MASK64 = (1 << 64) - 1

# splitmix64 constants (Steele et al.)
GOLDEN = 0x9E3779B97F4A7C15
MIX1 = 0xBF58476D1CE4E5B9
MIX2 = 0x94D049BB133111EB

# Seed-derivation domain separators (arbitrary odd constants; the Rust
# side uses the same values — see rust/src/hashing/mod.rs).
DOM_LEVEL = 0xA24BAED4963EE407
DOM_DEPTH = 0x9FB21C651E98DF25
DOM_CHECK = 0xD6E8FEB86659FD93


def _u64(x):
    if isinstance(x, int):
        x = x & MASK64
    return jnp.asarray(x, dtype=jnp.uint64)


def splitmix64(x):
    """The splitmix64 finalizer over uint64 arrays."""
    x = _u64(x)
    z = x + _u64(GOLDEN)
    z = (z ^ (z >> _u64(30))) * _u64(MIX1)
    z = (z ^ (z >> _u64(27))) * _u64(MIX2)
    return z ^ (z >> _u64(31))


def level_seed(graph_seed, level):
    """Seed for one sketch level (one CameoSketch repetition)."""
    return splitmix64(_u64(graph_seed) ^ (_u64(level) * _u64(DOM_LEVEL)))


def depth_seed(graph_seed, level, column):
    """Seed of the depth (row-choice) hash for (level, column)."""
    ls = level_seed(graph_seed, level)
    return splitmix64(ls ^ ((_u64(column) + _u64(1)) * _u64(DOM_DEPTH)))


def checksum_seed(graph_seed, level):
    """Seed of the per-level checksum hash (shared by the level's columns,
    matching the CameoSketch pseudocode where checksum = hash2(idx) is
    hoisted out of the column loop)."""
    ls = level_seed(graph_seed, level)
    return splitmix64(ls ^ _u64(DOM_CHECK))


def depth_hash(seed, idx):
    """Raw depth hash; row choice is geometric in its trailing zeros."""
    return splitmix64(_u64(seed) ^ _u64(idx))


def checksum(seed, idx):
    """Bucket checksum (the gamma XOR contribution of index ``idx``)."""
    return splitmix64(_u64(seed) ^ _u64(idx))


def bucket_depth(h, rows):
    """Map a depth hash to a row in [1, rows-1].

    P[depth = 1+t] = 2^-(t+1) via trailing zeros; h == 0 (probability
    2^-64) and overly deep values clamp to the deepest row.
    ctz(h) == popcount((h & -h) - 1).
    """
    h = _u64(h)
    lowbit = h & (_u64(0) - h)
    ctz = jax.lax.population_count(lowbit - _u64(1))
    depth = jnp.uint64(1) + jnp.minimum(ctz, _u64(rows - 2))
    return depth.astype(jnp.int32)
