"""Pure-numpy CameoSketch oracle — the correctness reference for the
Pallas kernel and (via shared golden fixtures) for the Rust native path.

Deliberately written as the *scalar* per-update procedure of the paper's
Fig. 12 pseudocode, one update at a time, with plain-int splitmix64 — a
fully independent code path from the vectorized kernel.
"""

from __future__ import annotations

import numpy as np

MASK64 = (1 << 64) - 1

GOLDEN = 0x9E3779B97F4A7C15
MIX1 = 0xBF58476D1CE4E5B9
MIX2 = 0x94D049BB133111EB
DOM_LEVEL = 0xA24BAED4963EE407
DOM_DEPTH = 0x9FB21C651E98DF25
DOM_CHECK = 0xD6E8FEB86659FD93


def splitmix64(x: int) -> int:
    z = (x + GOLDEN) & MASK64
    z = ((z ^ (z >> 30)) * MIX1) & MASK64
    z = ((z ^ (z >> 27)) * MIX2) & MASK64
    return z ^ (z >> 31)


def level_seed(graph_seed: int, level: int) -> int:
    return splitmix64(graph_seed ^ ((level * DOM_LEVEL) & MASK64))


def depth_seed(graph_seed: int, level: int, column: int) -> int:
    return splitmix64(
        level_seed(graph_seed, level) ^ (((column + 1) * DOM_DEPTH) & MASK64)
    )


def checksum_seed(graph_seed: int, level: int) -> int:
    return splitmix64(level_seed(graph_seed, level) ^ DOM_CHECK)


def checksum(seed: int, idx: int) -> int:
    return splitmix64(seed ^ idx)


def bucket_depth(h: int, rows: int) -> int:
    """Row in [1, rows-1]; P[row = 1+t] = 2^-(t+1) via trailing zeros."""
    if h == 0:
        return rows - 1
    ctz = (h & -h).bit_length() - 1
    return 1 + min(ctz, rows - 2)


def cameo_delta_ref(
    indices, graph_seed: int, levels: int, columns: int, rows: int
) -> np.ndarray:
    """Scalar-loop reference of the batched delta.

    Returns the same (L, C, R, 2) uint64 array the Pallas kernel produces.
    """
    out = np.zeros((levels, columns, rows, 2), dtype=np.uint64)
    for lvl in range(levels):
        cseed = checksum_seed(graph_seed, lvl)
        dseeds = [depth_seed(graph_seed, lvl, c) for c in range(columns)]
        for raw in indices:
            idx = int(raw)
            if idx == 0:  # padding sentinel
                continue
            chk = checksum(cseed, idx)
            for c in range(columns):
                h = splitmix64(dseeds[c] ^ idx)
                d = bucket_depth(h, rows)
                # deterministic bucket (row 0) + geometric bucket (row d)
                out[lvl, c, 0, 0] ^= np.uint64(idx)
                out[lvl, c, 0, 1] ^= np.uint64(chk)
                out[lvl, c, d, 0] ^= np.uint64(idx)
                out[lvl, c, d, 1] ^= np.uint64(chk)
    return out


def query_column(column_buckets, cseed: int):
    """Recover a nonzero index from one column, or None.

    A bucket (alpha, gamma) is *good* iff alpha != 0 and
    checksum(cseed, alpha) == gamma.  Scans deepest-first (the deepest
    good bucket is the most likely singleton).
    """
    rows = column_buckets.shape[0]
    for r in range(rows - 1, -1, -1):
        alpha = int(column_buckets[r, 0])
        gamma = int(column_buckets[r, 1])
        if alpha != 0 and checksum(cseed, alpha) == gamma:
            return alpha
    return None
