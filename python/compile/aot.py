"""AOT lowering: sketch-delta graph -> HLO text artifacts.

Run once at build time (``make artifacts``); Python is never on the
request path.  Emits one artifact per supported graph-size config plus a
manifest the Rust runtime uses to pick the right executable.

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model
from .params import (
    DEFAULT_BATCH,
    DEFAULT_COLUMNS,
    SEED_SCHEME_VERSION,
    SketchParams,
)

# Vertex counts the default artifact set covers: every power of two used
# by the examples and the bench harness.  (L, R) collapse many V values
# onto the same artifact shape; we dedupe below.
DEFAULT_VERTEX_CONFIGS = [1 << p for p in (8, 10, 11, 12, 13, 14, 16)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(params: SketchParams, batch: int) -> str:
    fn = model.make_delta_fn(params, batch)
    lowered = jax.jit(fn).lower(*model.example_args(params, batch))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--columns", type=int, default=DEFAULT_COLUMNS)
    ap.add_argument(
        "--vertices",
        type=int,
        nargs="*",
        default=DEFAULT_VERTEX_CONFIGS,
        help="vertex counts to cover (deduped by artifact shape)",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "seed_scheme_version": SEED_SCHEME_VERSION,
        "batch": args.batch,
        "artifacts": [],
    }
    seen_shapes = {}
    for v in sorted(args.vertices):
        params = SketchParams.for_vertices(v, columns=args.columns)
        shape_key = (params.levels, params.columns, params.rows)
        if shape_key in seen_shapes:
            name = seen_shapes[shape_key]
        else:
            name = (
                f"cameo_delta_B{args.batch}_L{params.levels}"
                f"_C{params.columns}_R{params.rows}.hlo.txt"
            )
            path = os.path.join(args.out_dir, name)
            text = lower_config(params, args.batch)
            with open(path, "w") as f:
                f.write(text)
            seen_shapes[shape_key] = name
            print(f"wrote {path} ({len(text)} chars)")
        manifest["artifacts"].append(
            {
                "vertices": v,
                "levels": params.levels,
                "columns": params.columns,
                "rows": params.rows,
                "batch": args.batch,
                "file": name,
            }
        )

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} configs)")


if __name__ == "__main__":
    main()
