"""Sketch parameter derivation — the cross-language contract.

This module is the single Python source of truth for how sketch shapes are
derived from the number of graph vertices V.  `rust/src/sketch/params.rs`
implements the *identical* derivation; `python/tests/test_hash_golden.py`
pins both against a shared JSON fixture.

Terminology (paper §4, App. B):
  * n = V*V            -- the characteristic-vector index space (we use
                          V*V rather than (V choose 2) so that encode /
                          decode are single multiplies; unused slots are
                          simply never touched).
  * L  "levels"        -- independent CameoSketch repetitions per vertex,
                          one consumed per Boruvka round:  ceil(log_{3/2} V).
  * C  "columns"       -- log(1/delta) columns per level (default 3).
  * R  "rows"          -- log2(n) + 6 bucket rows per column; row 0 is the
                          deterministic bucket that receives every update.
Each bucket is an (alpha, gamma) pair of u64.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

# Version tag for the seed-derivation scheme.  Bump if hashing changes;
# the Rust runtime refuses artifacts with a mismatched version.
SEED_SCHEME_VERSION = 1

# Default number of columns per level (delta = 3^-C per column group,
# see Theorem 4.3's log_3(1/delta) column count).
DEFAULT_COLUMNS = 3

# Default batch capacity compiled into the AOT artifact.  Workers chunk
# arbitrary batch sizes into B-sized pieces and XOR-merge the deltas
# (sketches are linear, so chunking is exact).
DEFAULT_BATCH = 512


def num_levels(v: int) -> int:
    """ceil(log_{3/2} V) sketch levels, min 1 (paper App. E.2)."""
    if v < 2:
        return 1
    return max(1, math.ceil(math.log(v) / math.log(1.5)))


def num_rows(v: int) -> int:
    """log2(n) + 6 rows where n = V^2; row 0 is the deterministic bucket."""
    n_bits = max(1, math.ceil(math.log2(max(4, v))) * 2)
    return n_bits + 6


@dataclass(frozen=True)
class SketchParams:
    """Shape of one vertex sketch for a V-vertex graph."""

    v: int
    levels: int
    columns: int
    rows: int

    @staticmethod
    def for_vertices(v: int, columns: int = DEFAULT_COLUMNS) -> "SketchParams":
        return SketchParams(
            v=v, levels=num_levels(v), columns=columns, rows=num_rows(v)
        )

    @property
    def buckets_per_level(self) -> int:
        return self.columns * self.rows

    @property
    def words_per_level(self) -> int:
        # (alpha, gamma) u64 pair per bucket
        return self.buckets_per_level * 2

    @property
    def words(self) -> int:
        return self.levels * self.words_per_level

    @property
    def bytes(self) -> int:
        return self.words * 8


def encode_edge(u: int, v: int, num_vertices: int) -> int:
    """Edge (u,v) -> characteristic-vector index.  0 is reserved as the
    padding sentinel, hence the +1 shift."""
    lo, hi = (u, v) if u < v else (v, u)
    assert 0 <= lo < hi < num_vertices
    return lo * num_vertices + hi + 1


def decode_edge(idx: int, num_vertices: int) -> tuple[int, int]:
    """Inverse of :func:`encode_edge`."""
    assert idx != 0
    raw = idx - 1
    return raw // num_vertices, raw % num_vertices
