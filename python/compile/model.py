"""L2 — the sketch-delta computation graph.

The "model" of this data-pipeline paper is not a neural network: it is the
linear map  batch of edge indices  ->  vertex-sketch delta  that the
distributed workers evaluate (paper §5.2).  This module assembles the L1
Pallas kernel into the jit-able function that ``aot.py`` lowers to HLO
text, plus helpers used by the tests.

Seeds are *runtime inputs* (not baked constants) so a single artifact per
(B, L, C, R) shape serves every graph seed and every k-connectivity copy.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from .kernels import cameo, hashing
from .params import SketchParams


def make_delta_fn(params: SketchParams, batch: int, interpret: bool = True):
    """Build the jit-able delta function for one sketch shape.

    Returns ``fn(idx[B] u64, dseeds[L,C] u64, cseeds[L] u64) ->
    (delta[L,C,R,2] u64,)`` — a 1-tuple, matching the return_tuple=True
    lowering convention the Rust loader expects.
    """

    def fn(idx, dseeds, cseeds):
        delta = cameo.cameo_delta(
            idx, dseeds, cseeds, rows=params.rows, interpret=interpret
        )
        return (delta,)

    return fn


def example_args(params: SketchParams, batch: int):
    """ShapeDtypeStructs used for lowering."""
    return (
        jax.ShapeDtypeStruct((batch,), jnp.uint64),
        jax.ShapeDtypeStruct((params.levels, params.columns), jnp.uint64),
        jax.ShapeDtypeStruct((params.levels,), jnp.uint64),
    )


def seeds_for(params: SketchParams, graph_seed: int):
    """Derive the (dseeds, cseeds) arrays for a graph seed — matches the
    Rust side's ``SketchSeeds::derive``."""
    dseeds = np.zeros((params.levels, params.columns), dtype=np.uint64)
    cseeds = np.zeros((params.levels,), dtype=np.uint64)
    for lvl in range(params.levels):
        cseeds[lvl] = np.uint64(
            int(hashing.checksum_seed(graph_seed, lvl))
        )
        for c in range(params.columns):
            dseeds[lvl, c] = np.uint64(
                int(hashing.depth_seed(graph_seed, lvl, c))
            )
    return dseeds, cseeds


def compute_delta(
    indices, params: SketchParams, graph_seed: int, batch: int | None = None
):
    """Convenience entry point: pad, run the kernel, XOR-merge chunks.

    Mirrors what a Rust worker does with the AOT artifact: chunk the batch
    into B-sized pieces and XOR the per-chunk deltas (linearity).
    """
    indices = np.asarray(indices, dtype=np.uint64)
    if batch is None:
        batch = max(8, len(indices))
    dseeds, cseeds = seeds_for(params, graph_seed)
    fn = jax.jit(make_delta_fn(params, batch))
    out = np.zeros((params.levels, params.columns, params.rows, 2), np.uint64)
    for start in range(0, max(1, len(indices)), batch):
        chunk = indices[start : start + batch]
        padded = np.zeros((batch,), dtype=np.uint64)
        padded[: len(chunk)] = chunk
        (delta,) = fn(jnp.asarray(padded), jnp.asarray(dseeds), jnp.asarray(cseeds))
        out ^= np.asarray(delta)
    return out
