"""Generate the cross-language golden fixtures shared with the Rust tests.

Run from python/:  python gen_fixtures.py
Writes ../tests/fixtures/{hash_golden.json, delta_golden.json}.

These fixtures pin the exact hashing and sketch-delta bit patterns; the
Rust unit tests (rust/src/hashing, rust/src/sketch) parse them and must
reproduce every value.  Regenerate only if the seed scheme version bumps.
"""

from __future__ import annotations

import json
import os

import numpy as np

from compile.kernels import ref
from compile.params import SketchParams, encode_edge

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "tests", "fixtures")


def hash_golden():
    inputs = [0, 1, 2, 63, 64, 0xDEADBEEF, (1 << 64) - 1, 0x0123456789ABCDEF]
    entries = []
    for x in inputs:
        entries.append({"x": str(x), "splitmix64": str(ref.splitmix64(x))})
    seeds = []
    for graph_seed in (0, 42, 0xC0FFEE):
        for level in (0, 1, 7):
            for col in (0, 1, 2):
                seeds.append(
                    {
                        "graph_seed": str(graph_seed),
                        "level": level,
                        "column": col,
                        "level_seed": str(ref.level_seed(graph_seed, level)),
                        "depth_seed": str(ref.depth_seed(graph_seed, level, col)),
                        "checksum_seed": str(ref.checksum_seed(graph_seed, level)),
                    }
                )
    depths = []
    for h in (0, 1, 2, 4, 8, 0xF0, 1 << 40, (1 << 64) - 1):
        for rows in (8, 22, 40):
            depths.append({"h": str(h), "rows": rows, "depth": ref.bucket_depth(h, rows)})
    return {"splitmix64": entries, "seeds": seeds, "depths": depths}


def delta_golden():
    v = 64
    params = SketchParams.for_vertices(v)
    graph_seed = 1234567
    edges = [(0, 1), (0, 2), (1, 2), (5, 9), (62, 63), (0, 63)]
    indices = [encode_edge(a, b, v) for a, b in edges]
    delta = ref.cameo_delta_ref(
        indices, graph_seed, params.levels, params.columns, params.rows
    )
    return {
        "vertices": v,
        "graph_seed": str(graph_seed),
        "levels": params.levels,
        "columns": params.columns,
        "rows": params.rows,
        "edges": [[a, b] for a, b in edges],
        "indices": [str(i) for i in indices],
        # flattened row-major (L, C, R, 2) as decimal strings
        "delta": [str(int(x)) for x in np.asarray(delta).reshape(-1)],
    }


def main() -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "hash_golden.json"), "w") as f:
        json.dump(hash_golden(), f, indent=1)
    with open(os.path.join(OUT_DIR, "delta_golden.json"), "w") as f:
        json.dump(delta_golden(), f, indent=1)
    print(f"fixtures written to {os.path.abspath(OUT_DIR)}")


if __name__ == "__main__":
    main()
