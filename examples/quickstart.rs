//! Quickstart: sketch a dense dynamic graph stream and query its
//! connected components.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use landscape::coordinator::{Coordinator, CoordinatorConfig};
use landscape::stream::dynamify::Dynamify;
use landscape::stream::erdos::ErdosRenyi;
use landscape::stream::GraphStream;

fn main() -> anyhow::Result<()> {
    // A dense dynamic graph: G(4096, 1/2) whose edges are inserted and
    // deleted 3 times over (net effect: the final graph).
    let vertices = 1u64 << 12;
    let model = ErdosRenyi::new(vertices, 0.5, 42);
    let stream = Dynamify::new(model, 3);
    println!(
        "stream: V={vertices}, ~{} updates",
        stream.len_hint().unwrap_or(0)
    );

    // The coordinator: sketches on the main node, CPU work distributed
    // to (in-process) workers.
    let mut coord = Coordinator::new(CoordinatorConfig::for_vertices(vertices))?;
    println!(
        "sketch memory: {} total ({} per vertex) — independent of edge count",
        landscape::benchkit::fmt_bytes(coord.sketch_bytes() as f64),
        landscape::benchkit::fmt_bytes(coord.params().bytes() as f64),
    );

    let report = coord.ingest_all(stream);
    println!(
        "ingested {} updates in {:.2}s ({})",
        report.updates,
        report.seconds,
        landscape::benchkit::fmt_rate(report.rate())
    );

    // Global connectivity query.
    let forest = coord.connected_components();
    println!(
        "connected components: {} ({} spanning-forest edges)",
        forest.num_components(),
        forest.edges.len()
    );

    // Batched reachability.
    let answers = coord.reachability(&[(0, 1), (0, 2048), (1, 4095)]);
    println!("reachability [(0,1),(0,2048),(1,4095)] = {answers:?}");

    let m = coord.metrics();
    println!(
        "network: {:.2}x the input stream ({} batches to workers)",
        m.communication_factor(),
        m.batches_sent
    );
    Ok(())
}
