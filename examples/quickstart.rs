//! Quickstart: sketch a dense dynamic graph stream through concurrent
//! producers and query its connected components — the session API in
//! one page.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use landscape::stream::dynamify::Dynamify;
use landscape::stream::erdos::ErdosRenyi;
use landscape::stream::GraphStream;
use landscape::Landscape;

fn main() -> anyhow::Result<()> {
    // A dense dynamic graph: G(4096, 1/2) whose edges are inserted and
    // deleted 3 times over (net effect: the final graph).
    let vertices = 1u64 << 12;
    let producers = 4u64;
    let model = ErdosRenyi::new(vertices, 0.5, 42);
    println!(
        "stream: V={vertices}, ~{} updates, {producers} producers",
        Dynamify::new(model, 3).len_hint().unwrap_or(0)
    );

    // The session: validated build, sketches on the main node, CPU work
    // distributed to (in-process) workers.  Invalid knobs are typed
    // errors, not panics — e.g. `.vertices(0)` returns
    // `Err(ConfigError::ZeroVertices)`.
    let session = Landscape::builder().vertices(vertices).build()?;
    println!(
        "sketch memory: {} total ({} per vertex) — independent of edge count",
        landscape::benchkit::fmt_bytes(session.sketch_bytes() as f64),
        landscape::benchkit::fmt_bytes(session.params().bytes() as f64),
    );

    // N concurrent producers, each with its own Send ingest handle.
    // ErdosRenyi is Copy, so every thread re-derives its stream slice.
    let sw = landscape::util::timer::Stopwatch::new();
    std::thread::scope(|scope| {
        for p in 0..producers {
            let mut handle = session.ingest_handle();
            scope.spawn(move || {
                for (i, u) in Dynamify::new(model, 3).enumerate() {
                    if i as u64 % producers == p {
                        handle.ingest(u);
                    }
                }
            }); // dropping the handle publishes its tail
        }
    });
    session.flush(); // barrier: every update has reached a sketch
    let m = session.metrics();
    println!(
        "ingested {} updates in {:.2}s ({}) across {} handles",
        m.updates_ingested,
        sw.elapsed_secs(),
        landscape::benchkit::fmt_rate(m.updates_ingested as f64 / sw.elapsed_secs()),
        m.handles_spawned,
    );

    // Read side: no &mut access to ingestion, cloneable across threads.
    let queries = session.query_handle();
    let forest = queries.connected_components();
    println!(
        "connected components: {} ({} spanning-forest edges)",
        forest.num_components(),
        forest.edges.len()
    );

    // Batched reachability.
    let answers = queries.reachability(&[(0, 1), (0, 2048), (1, 4095)]);
    println!("reachability [(0,1),(0,2048),(1,4095)] = {answers:?}");

    let m = session.metrics();
    println!(
        "network: {:.2}x the input stream ({} batches to workers)",
        m.communication_factor(),
        m.batches_sent
    );
    Ok(())
}
