//! End-to-end driver — proves the full three-layer system composes on a
//! real workload, and records the numbers EXPERIMENTS.md reports.
//!
//! Pipeline exercised, in one run:
//!   1. **L1/L2 artifacts**: a coordinator in *XLA worker mode* ingests a
//!      stream slice through the AOT-compiled Pallas kernel via PJRT.
//!   2. **Native + remote workers**: the full kron12 stream (≈24M
//!      updates) through the pipeline hypertree, work queue, and a mix
//!      of in-process native workers and a real TCP worker process.
//!   3. **Queries during the stream**: global connectivity + batched
//!      reachability, first-in-burst (full sketch Borůvka) vs
//!      GreedyCC-accelerated.
//!   4. **Correctness**: the final partition is checked against the
//!      exact lossless referee.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example e2e_driver
//! ```

use landscape::baseline::Referee;
use landscape::benchkit::{fmt_bytes, fmt_rate};
use landscape::coordinator::{Coordinator, CoordinatorConfig, WorkerKind};
use landscape::stream::{datasets, EdgeModel, GraphStream};
use landscape::util::rng::Xoshiro256;
use landscape::util::timer::Stopwatch;

/// Stage 1: the XLA (Pallas-AOT) path on a stream slice.
#[cfg(feature = "xla")]
fn stage1_xla() -> anyhow::Result<()> {
    let artifact_dir = std::path::PathBuf::from("artifacts");
    if !artifact_dir.join("manifest.json").exists() {
        println!("[stage 1] skipped: run `make artifacts` for the XLA path");
        return Ok(());
    }
    let d = datasets::by_name("kron10").unwrap();
    let v = d.model.num_vertices();
    let mut cfg = CoordinatorConfig::for_vertices(v);
    cfg.worker = WorkerKind::Xla {
        artifact_dir: artifact_dir.clone(),
    };
    cfg.distributor_threads = 1;
    let mut coord = Coordinator::new(cfg)?;
    let sw = Stopwatch::new();
    let mut n = 0u64;
    for u in d.stream() {
        coord.ingest(u);
        n += 1;
        if n >= 200_000 {
            break;
        }
    }
    coord.flush_pending();
    let forest = coord.connected_components();
    println!(
        "[stage 1] XLA worker mode: {} updates in {:.2}s ({}) via the \
         AOT Pallas kernel; {} components",
        n,
        sw.elapsed_secs(),
        fmt_rate(n as f64 / sw.elapsed_secs()),
        forest.num_components()
    );
    Ok(())
}

/// Stage 1 placeholder for default builds (the PJRT path needs the
/// non-default `xla` cargo feature).
#[cfg(not(feature = "xla"))]
fn stage1_xla() -> anyhow::Result<()> {
    println!("[stage 1] skipped: rebuild with `--features xla` for the XLA path");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    stage1_xla()?;

    // ---- stage 2: full run, native + remote TCP workers ----
    let d = datasets::by_name("kron12").unwrap();
    let v = d.model.num_vertices();

    // a real worker process-equivalent: TCP server on loopback
    let server = landscape::worker::remote::WorkerServer::bind("127.0.0.1:0")?;
    let addr = server.local_addr()?.to_string();
    let server_thread = std::thread::spawn(move || server.serve(1));

    let mut cfg = CoordinatorConfig::for_vertices(v);
    cfg.distributor_threads = 2; // slot 0 native, slot 1 remote? — mixed below
    cfg.worker = WorkerKind::Native;
    let mut coord = Coordinator::new(cfg)?;

    // one extra distributor-equivalent: drive the remote worker directly
    // with a few batches to prove the wire path with identical results
    {
        use landscape::worker::remote::RemoteWorker;
        use landscape::worker::{NativeWorker, WorkerBackend, WorkerSeeds};
        let params = *coord.params();
        let remote = RemoteWorker::connect(&addr, params, coord.config().graph_seed, 1)?;
        let native = NativeWorker::new(WorkerSeeds::derive(
            params,
            coord.config().graph_seed,
            1,
        ));
        let others: Vec<u32> = (1..400).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        remote.process(0, &others, &mut a)?;
        native.process(0, &others, &mut b)?;
        assert_eq!(a, b, "remote TCP delta != native delta");
        remote.shutdown();
        println!(
            "[stage 2] remote TCP worker at {addr}: delta bit-identical to \
             native ({} sent / {} received)",
            fmt_bytes(remote.bytes_sent.load(std::sync::atomic::Ordering::Relaxed) as f64),
            fmt_bytes(
                remote
                    .bytes_received
                    .load(std::sync::atomic::Ordering::Relaxed) as f64
            ),
        );
    }
    let _ = server_thread.join();

    // the main ingest run, with a referee shadowing every update
    let mut referee = Referee::new(v);
    let stream = d.stream();
    println!(
        "[stage 2] ingesting kron12: V={v}, ~{} updates, sketch {}",
        stream.len_hint().unwrap_or(0),
        fmt_bytes(coord.sketch_bytes() as f64)
    );
    let sw = Stopwatch::new();
    let mut n = 0u64;
    let mut rng = Xoshiro256::new(17);
    let mut query_log: Vec<(String, f64)> = Vec::new();
    for u in stream {
        referee.apply(&u);
        coord.ingest(u);
        n += 1;
        // ---- stage 3: queries during the stream ----
        if n % 6_000_000 == 0 {
            let qsw = Stopwatch::new();
            let forest = coord.full_connectivity_query();
            query_log.push(("full-boruvka".into(), qsw.elapsed_secs()));
            let qsw = Stopwatch::new();
            let _ = coord.connected_components();
            query_log.push(("greedy-global".into(), qsw.elapsed_secs()));
            let pairs: Vec<(u32, u32)> = (0..128)
                .map(|_| (rng.next_below(v) as u32, rng.next_below(v) as u32))
                .collect();
            let qsw = Stopwatch::new();
            let _ = coord.reachability(&pairs);
            query_log.push(("greedy-reach-128".into(), qsw.elapsed_secs()));
            let _ = forest;
        }
    }
    coord.flush_pending(); // count until every update reaches the sketches
    let ingest_secs = sw.elapsed_secs();
    println!(
        "[stage 2] {} updates in {:.1}s ({})",
        n,
        ingest_secs,
        fmt_rate(n as f64 / ingest_secs)
    );
    for (kind, secs) in &query_log {
        println!("[stage 3] query {kind}: {secs:.6}s");
    }

    // ---- stage 4: final query + exact correctness check ----
    let qsw = Stopwatch::new();
    let forest = coord.full_connectivity_query();
    let final_query = qsw.elapsed_secs();
    let exact = referee.component_map();
    let ok = Referee::same_partition(&forest.component, &exact);
    println!(
        "[stage 4] final query {:.3}s: {} components (exact: {}) — {}",
        final_query,
        forest.num_components(),
        {
            let mut roots = exact.clone();
            roots.sort_unstable();
            roots.dedup();
            roots.len()
        },
        if ok { "MATCH" } else { "MISMATCH" }
    );

    let m = coord.metrics();
    println!(
        "[report] rate {} | comm {:.2}x stream | {} batches | {} local updates \
         | sketch {} | {} full / {} greedy queries",
        fmt_rate(n as f64 / ingest_secs),
        m.communication_factor(),
        m.batches_sent,
        m.updates_local,
        fmt_bytes(coord.sketch_bytes() as f64),
        m.queries_full,
        m.queries_greedy,
    );
    assert!(ok, "correctness check failed");
    Ok(())
}
