//! End-to-end driver — proves the full three-layer system composes on a
//! real workload, and records the numbers EXPERIMENTS.md reports.
//!
//! Pipeline exercised, in one run:
//!   1. **L1/L2 artifacts**: a coordinator in *XLA worker mode* ingests a
//!      stream slice through the AOT-compiled Pallas kernel via PJRT.
//!   2. **Native + remote workers**: the full kron12 stream (≈24M
//!      updates) through the pipeline hypertree, work queue, and a mix
//!      of in-process native workers and a real TCP worker process.
//!   3. **Queries during the stream**: global connectivity + batched
//!      reachability, first-in-burst (full sketch Borůvka) vs
//!      GreedyCC-accelerated.
//!   4. **Correctness**: the final partition is checked against the
//!      exact lossless referee.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example e2e_driver
//! ```
//!
//! `--scenario query` runs only stage 0 (the tiered-query scenario) on a
//! small graph — the CI-sized proof that all three query tiers answer
//! correctly on a mixed insert/delete/query workload.
//!
//! `--scenario remote` runs only the pipelined remote-transport scenario:
//! in-process worker servers with injected reply latency, a window of
//! batches in flight, out-of-order delta completion, and a mid-stream
//! worker crash absorbed by failover — checked against the exact referee.
//!
//! `--scenario snapshot` runs only the epoch-cut scenario: pinned
//! snapshots and forced tier-2 queries racing sustained, never-idle
//! 4-producer ingest, each answer checked against the DSU referee and
//! held to a promptness bound (the retired idle-waiting barrier hangs
//! here).
//!
//! `--scenario sparse` runs only the hybrid vertex-tier scenario: the
//! skewed kron10 stream through a session with the adaptive
//! sparse/dense representation on, followed by a targeted deletion
//! phase — promotions AND demotions must both be metered, every answer
//! must match the exact referee, and the resident store bytes are
//! reported against the analytic all-sketch figure.
//!
//! `--scenario recovery` runs only the crash-recovery scenario: the
//! driver re-spawns itself as a child (`--scenario recovery-child`)
//! that ingests a deterministic spill-mode stream, takes one durable
//! cut partway, keeps merging past it, and then `process::abort()`s —
//! a real kill, no destructors.  The parent reopens the storage
//! directory with [`Landscape::recover`], replays the rest of the
//! stream, and the final partition must match the exact referee with
//! zero metered batch loss.
//!
//! `--scenario tenants` runs only the multi-tenant serving scenario:
//! three logical graphs multiplexed over ONE shared fabric (shared
//! distributor pool, real TCP worker servers), driven end-to-end
//! through the length-prefixed TCP front end.  The quota'd hot tenant
//! must collect metered rejections with nothing silently dropped, an
//! idle tenant's snapshot must stay prompt while the hot tenant
//! saturates, every tenant must match its own exact referee, and the
//! per-tenant TBATCH2/TDELTA2 byte accounting must keep the
//! Theorem 5.2 bound **per tenant**.

use landscape::baseline::Referee;
use landscape::benchkit::{fmt_bytes, fmt_rate};
use landscape::coordinator::{CoordinatorConfig, QueryTier, WorkerKind};
use landscape::session::{IngestHandle, Landscape, QueryHandle};
use landscape::stream::update::Update;
use landscape::stream::{datasets, EdgeModel, GraphStream};
use landscape::util::rng::Xoshiro256;
use landscape::util::timer::Stopwatch;

/// Stage 1: the XLA (Pallas-AOT) path on a stream slice.
#[cfg(feature = "xla")]
fn stage1_xla() -> anyhow::Result<()> {
    let artifact_dir = std::path::PathBuf::from("artifacts");
    if !artifact_dir.join("manifest.json").exists() {
        println!("[stage 1] skipped: run `make artifacts` for the XLA path");
        return Ok(());
    }
    let d = datasets::by_name("kron10").unwrap();
    let v = d.model.num_vertices();
    let mut cfg = CoordinatorConfig::for_vertices(v);
    cfg.worker = WorkerKind::Xla {
        artifact_dir: artifact_dir.clone(),
    };
    cfg.distributor_threads = 1;
    let session = Landscape::from_config(cfg)?;
    let mut ingest = session.ingest_handle();
    let sw = Stopwatch::new();
    let mut n = 0u64;
    for u in d.stream() {
        ingest.ingest(u);
        n += 1;
        if n >= 200_000 {
            break;
        }
    }
    ingest.flush();
    session.flush();
    let forest = session.query_handle().connected_components();
    println!(
        "[stage 1] XLA worker mode: {} updates in {:.2}s ({}) via the \
         AOT Pallas kernel; {} components",
        n,
        sw.elapsed_secs(),
        fmt_rate(n as f64 / sw.elapsed_secs()),
        forest.num_components()
    );
    Ok(())
}

/// Stage 1 placeholder for default builds (the PJRT path needs the
/// non-default `xla` cargo feature).
#[cfg(not(feature = "xla"))]
fn stage1_xla() -> anyhow::Result<()> {
    println!("[stage 1] skipped: rebuild with `--features xla` for the XLA path");
    Ok(())
}

/// Stage 0: the tiered query path on a mixed insert/delete/query
/// workload (V = 2^12), exercising all three tiers:
///
/// * tier 0 (GreedyCC) — queries on the clean graph and after a
///   non-forest (cycle-edge) deletion;
/// * tier 1 (partial) — after forest-edge deletions dirty a few
///   components, the query flushes and warm-starts Borůvka over the
///   dirty region only;
/// * tier 2 (full) — a forced full flush + Borůvka for comparison.
///
/// Every partition is checked against the exact referee, and the run
/// asserts that no batch was dropped at the queue boundary.
fn stage0_query_tiers() -> anyhow::Result<()> {
    let v = 1u64 << 12;
    let session = Landscape::builder().vertices(v).alpha(1).build()?;
    let mut producer = session.ingest_handle();
    let queries = session.query_handle();
    let mut referee = Referee::new(v);
    let ingest = |producer: &mut IngestHandle, referee: &mut Referee, u: Update| {
        referee.apply(&u);
        producer.ingest(u);
    };

    // 64 disjoint paths of 64 vertices, plus a chord per path (cycle edge)
    let comp = 64u32;
    let span = (v as u32) / comp;
    for c in 0..comp {
        let base = c * span;
        for i in 0..span - 1 {
            ingest(&mut producer, &mut referee, Update::insert(base + i, base + i + 1));
        }
        ingest(&mut producer, &mut referee, Update::insert(base, base + 2));
    }

    let check = |producer: &mut IngestHandle,
                 queries: &QueryHandle,
                 referee: &Referee,
                 label: &str| {
        producer.flush();
        let sw = Stopwatch::new();
        let forest = queries.connected_components();
        let secs = sw.elapsed_secs();
        let ok = Referee::same_partition(&forest.component, &referee.component_map());
        println!(
            "[stage 0] {label}: {:.6}s, {} components — {}",
            secs,
            forest.num_components(),
            if ok { "MATCH" } else { "MISMATCH" }
        );
        assert!(ok, "stage 0 ({label}): partition mismatch");
    };

    // tier 0: clean graph (publish the producer tail before planning)
    producer.flush();
    assert_eq!(queries.query_plan(), QueryTier::Greedy);
    check(&mut producer, &queries, &referee, "tier0 greedy (clean)");

    // tier 0 after a non-forest deletion: the chord of path 0 is a cycle
    // edge, so the query must stay on the greedy tier (no flush/Borůvka)
    let full_before = session.metrics().queries_full;
    let partial_before = session.metrics().queries_partial;
    ingest(&mut producer, &mut referee, Update::delete(0, 2));
    producer.flush();
    assert_eq!(queries.query_plan(), QueryTier::Greedy);
    check(&mut producer, &queries, &referee, "tier0 greedy (after non-forest delete)");
    assert_eq!(session.metrics().queries_full, full_before);
    assert_eq!(session.metrics().queries_partial, partial_before);

    // tier 1: delete one forest edge in each of 4 paths
    for c in 0..4u32 {
        let mid = c * span + span / 2;
        ingest(&mut producer, &mut referee, Update::delete(mid, mid + 1));
    }
    producer.flush();
    assert_eq!(queries.query_plan(), QueryTier::Partial);
    check(&mut producer, &queries, &referee, "tier1 partial (4 dirty / 64 components)");
    assert_eq!(session.metrics().queries_partial, partial_before + 1);

    // tier 2: forced full query on the same state
    let sw = Stopwatch::new();
    let forest = queries.full_connectivity_query();
    println!(
        "[stage 0] tier2 full (forced): {:.6}s, {} components",
        sw.elapsed_secs(),
        forest.num_components()
    );
    assert!(Referee::same_partition(
        &forest.component,
        &referee.component_map()
    ));

    let m = session.metrics();
    assert_eq!(m.batches_dropped, 0, "batches silently dropped during the run");
    println!(
        "[stage 0] tiers exercised: {} greedy / {} partial / {} full; \
         {} components marked dirty; 0 dropped batches",
        m.queries_greedy, m.queries_partial, m.queries_full, m.dirty_components
    );
    Ok(())
}

/// The pipelined remote-worker scenario (CI-sized): two worker servers
/// with 200µs injected reply latency, one of which crashes its
/// connection mid-stream; the coordinator must pipeline (peak in-flight
/// ≥ 2), fail over with every unacknowledged batch requeued, drop
/// nothing, and still match the exact referee.
fn stage_remote() -> anyhow::Result<()> {
    use landscape::stream::dynamify::Dynamify;
    use landscape::stream::erdos::ErdosRenyi;
    use landscape::worker::remote::{ServeOptions, WorkerServer};
    use std::time::Duration;

    // p is chosen so per-vertex leaves clear the γ-flush threshold
    // (3·E[deg] ≈ 307 ≥ γ·capacity ≈ 225 at V=1024) and batches really
    // cross the wire
    let v = 1u64 << 10;
    let model = ErdosRenyi::new(v, 0.1, 8080);
    let latency = Duration::from_micros(200);

    let flaky = WorkerServer::bind_with(
        "127.0.0.1:0",
        ServeOptions {
            reply_latency: latency,
            fail_after_batches: Some(4),
        },
    )?;
    let healthy = WorkerServer::bind_with(
        "127.0.0.1:0",
        ServeOptions {
            reply_latency: latency,
            fail_after_batches: None,
        },
    )?;
    let addrs = vec![
        flaky.local_addr()?.to_string(),
        healthy.local_addr()?.to_string(),
    ];
    let flaky_thread = std::thread::spawn(move || flaky.serve(1));
    let healthy_thread = std::thread::spawn(move || healthy.serve(2));

    let mut cfg = CoordinatorConfig::for_vertices(v);
    cfg.alpha = 1;
    cfg.distributor_threads = 2;
    cfg.use_greedycc = false;
    cfg.remote_window = 8;
    cfg.worker = WorkerKind::Remote { addrs };
    let session = Landscape::from_config(cfg)?;
    let mut ingest = session.ingest_handle();

    let mut referee = Referee::new(v);
    let sw = Stopwatch::new();
    let mut n = 0u64;
    for u in Dynamify::new(model, 3) {
        referee.apply(&u);
        ingest.ingest(u);
        n += 1;
    }
    ingest.flush();
    let forest = session.query_handle().full_connectivity_query();
    let secs = sw.elapsed_secs();
    let ok = Referee::same_partition(&forest.component, &referee.component_map());
    let m = session.metrics();
    println!(
        "[remote] {} updates in {:.2}s ({}) over pipelined TCP (window 8, \
         200µs injected reply latency): {} batches, peak {} in flight, \
         {} worker failure(s), {} requeued, {} dropped — {}",
        n,
        secs,
        fmt_rate(n as f64 / secs),
        m.batches_sent,
        m.remote_in_flight_peak,
        m.worker_failures,
        m.batches_requeued,
        m.batches_dropped,
        if ok { "MATCH" } else { "MISMATCH" },
    );
    assert!(ok, "remote scenario: partition mismatch");
    assert_eq!(m.batches_dropped, 0, "remote scenario dropped batches");
    assert!(m.worker_failures >= 1, "injected crash not observed");
    assert!(m.batches_requeued >= 1, "no batches requeued after the crash");
    assert!(
        m.remote_in_flight_peak >= 2,
        "transport never pipelined (peak in-flight < 2)"
    );
    drop(ingest);
    drop(session); // closes the surviving connections so the servers exit
    let _ = flaky_thread.join();
    let _ = healthy_thread.join();
    Ok(())
}

/// The snapshot scenario (CI-sized): queries racing sustained,
/// never-idle 4-producer ingest.  A base graph of disjoint cycles is
/// published; the producers then churn partition-invariant chords
/// (insert→delete inside a cycle, producer-disjoint chord sets,
/// publishing every round) so the shared pipeline never goes idle.
/// The main thread meanwhile takes pinned [`landscape::Snapshot`]s and
/// forced tier-2 queries — each must return promptly (bounded by
/// in-flight work at cut time, not by the stream, which never ends on
/// its own) and match the DSU referee of the base graph.  Under the
/// retired idle-waiting barrier this scenario hangs.
fn stage_snapshot() -> anyhow::Result<()> {
    use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    let producers = 4usize;
    let cycles = 32u32;
    let span = 32u32;
    let v = (cycles * span) as u64;
    let rounds = 8usize;

    let session = Landscape::builder()
        .vertices(v)
        .alpha(1)
        .distributor_threads(2)
        .update_log_capacity(64)
        .build()?;

    let base = landscape::util::testkit::cycle_graph(cycles, span);
    let mut referee = Referee::new(v);
    for u in &base {
        referee.apply(u);
    }
    let want = referee.component_map();

    let stop = AtomicBool::new(false);
    let published = AtomicUsize::new(0);
    let churned = AtomicU64::new(0);
    let mut max_snap = Duration::ZERO;
    let mut max_full = Duration::ZERO;
    let mismatch = std::thread::scope(|scope| {
        for p in 0..producers {
            let mut handle = session.ingest_handle();
            let chunk: Vec<Update> = base
                .iter()
                .copied()
                .skip(p)
                .step_by(producers)
                .collect();
            let (stop, published, churned) = (&stop, &published, &churned);
            scope.spawn(move || {
                for u in chunk {
                    handle.ingest(u);
                }
                handle.flush();
                published.fetch_add(1, Ordering::Release);
                // never-idle phase: toggle this producer's chords,
                // publishing every round so batches keep flowing
                let mut n = 0u64;
                let mut i = 0u32;
                while !stop.load(Ordering::Acquire) {
                    let (x, y) =
                        landscape::util::testkit::churn_chord((i % cycles) * span, p, span);
                    handle.ingest(Update::insert(x, y));
                    handle.ingest(Update::delete(x, y));
                    handle.flush();
                    n += 2;
                    i += 1;
                }
                churned.fetch_add(n, Ordering::Relaxed);
            });
        }

        while published.load(Ordering::Acquire) < producers {
            std::thread::sleep(Duration::from_millis(1));
        }

        // record the first mismatch instead of asserting mid-scope: a
        // panic before `stop` is set would wedge the scope behind the
        // still-spinning producers
        let mut mismatch: Option<String> = None;
        let queries = session.query_handle();
        for round in 0..rounds {
            // pinned snapshot: cheap cut, bounded wait, referee-correct
            let t0 = Instant::now();
            let snap = queries.snapshot();
            let sf = snap.connected_components();
            max_snap = max_snap.max(t0.elapsed());
            if !Referee::same_partition(&sf.component, &want) && mismatch.is_none() {
                mismatch = Some(format!("snapshot round {round}"));
            }

            // forced tier-2 on a fresh cut: the worst-case barrier path
            let t0 = Instant::now();
            let ff = queries.full_connectivity_query();
            max_full = max_full.max(t0.elapsed());
            if !Referee::same_partition(&ff.component, &want) && mismatch.is_none() {
                mismatch = Some(format!("tier-2 round {round}"));
            }
        }
        stop.store(true, Ordering::Release);
        mismatch
    });

    if let Some(at) = mismatch {
        panic!("{at}: partition mismatch under load");
    }
    let m = session.metrics();
    println!(
        "[snapshot] {rounds} snapshot + {rounds} tier-2 queries while {} \
         producers churned {} updates without pausing: max snapshot \
         latency {:.6}s, max tier-2 latency {:.6}s, {} cuts (epoch {}), \
         total cut-wait {:.6}s, {} dropped — MATCH",
        producers,
        churned.load(Ordering::Relaxed),
        max_snap.as_secs_f64(),
        max_full.as_secs_f64(),
        m.cuts_taken,
        m.epoch_current,
        m.cut_wait_us as f64 / 1e6,
        m.batches_dropped,
    );
    assert_eq!(m.batches_dropped, 0, "snapshot scenario dropped batches");
    assert!(
        m.cuts_taken >= rounds as u64 * 2,
        "every snapshot and tier-2 query must take its own cut"
    );
    assert!(
        m.epoch_current >= rounds as u64,
        "cuts must advance the epoch"
    );
    // the hang this scenario regression-tests manifested as an unbounded
    // stall; any sane bound proves promptness on CI hardware
    assert!(
        max_snap < Duration::from_secs(20) && max_full < Duration::from_secs(20),
        "query under sustained load exceeded the promptness bound \
         (snapshot {max_snap:?}, tier-2 {max_full:?})"
    );
    Ok(())
}

/// The hybrid vertex-tier scenario (CI-sized): the kron10 stream —
/// Kronecker degrees are heavily skewed, so the hybrid store holds a
/// genuine mix of exact and promoted vertices — through a session with
/// the adaptive representation on, then a targeted deletion phase.
///
/// The promotion threshold is sized from the deterministic edge model:
/// the lowest-degree vertex with degree in `5..=64` becomes the
/// demotion target, and `threshold = degree - 1` guarantees that (a)
/// the stream promotes it, (b) its demotion shadow (bounded by its
/// degree, which sits under the shadow cap) stays tracked, and (c)
/// deleting its edges afterwards drops it below the hysteresis floor —
/// so the run must meter promotions *and* demotions, deterministically.
/// Queries mid-stream and after the deletions are checked against the
/// exact referee, and the resident store footprint is compared against
/// the analytic all-sketch figure.
fn stage_sparse() -> anyhow::Result<()> {
    let d = datasets::by_name("kron10").unwrap();
    let v = d.model.num_vertices();

    // final-graph degrees, straight from the deterministic edge model
    let edges = landscape::stream::edge_list(&d.model);
    let mut degree = vec![0u32; v as usize];
    for &(a, b) in &edges {
        degree[a as usize] += 1;
        degree[b as usize] += 1;
    }
    let (target, tdeg) = degree
        .iter()
        .enumerate()
        .filter(|&(_, &dg)| (5..=64).contains(&dg))
        .map(|(u, &dg)| (u as u32, dg))
        .min_by_key(|&(_, dg)| dg)
        .expect("kron10 has no vertex with final degree in 5..=64");
    let threshold = tdeg - 1;

    let session = Landscape::builder()
        .vertices(v)
        .alpha(1)
        .distributor_threads(2)
        .hybrid_threshold(threshold)
        .build()?;
    let mut producer = session.ingest_handle();
    let queries = session.query_handle();
    let mut referee = Referee::new(v);

    let stream = d.stream();
    let total = stream.len_hint().unwrap_or(0);
    let sw = Stopwatch::new();
    let mut n = 0u64;
    for u in stream {
        referee.apply(&u);
        producer.ingest(u);
        n += 1;
        // a mid-stream query while tiers are mixed and still churning
        if n == total / 2 {
            producer.flush();
            let forest = queries.connected_components();
            assert!(
                Referee::same_partition(&forest.component, &referee.component_map()),
                "sparse scenario: mid-stream partition mismatch"
            );
        }
    }
    producer.flush();
    session.flush();
    let ingest_secs = sw.elapsed_secs();

    let forest = queries.connected_components();
    assert!(
        Referee::same_partition(&forest.component, &referee.component_map()),
        "sparse scenario: post-stream partition mismatch"
    );
    let m = session.metrics();
    println!(
        "[sparse] kron10 ({} updates in {:.2}s, {}) with hybrid threshold \
         {threshold}: {} exact / {} sketched vertices, {} promotions, \
         resident store {} + {} exact vs {} all-sketch",
        n,
        ingest_secs,
        fmt_rate(n as f64 / ingest_secs),
        m.vertices_exact,
        m.vertices_sketched,
        m.promotions,
        fmt_bytes(m.store_sketch_bytes as f64),
        fmt_bytes(m.store_exact_bytes as f64),
        fmt_bytes(
            (v as usize * session.params().words() * 8 * session.config().k as usize) as f64
        ),
    );
    assert!(m.promotions > 0, "skewed kron degrees must promote vertices");
    assert_eq!(
        m.vertices_exact + m.vertices_sketched,
        v,
        "every vertex sits in exactly one tier"
    );

    // deletion phase: strip the target vertex bare — its tracked shadow
    // shrinks below the hysteresis floor, forcing a demotion
    for &(a, b) in edges.iter().filter(|&&(a, b)| a == target || b == target) {
        let u = Update::delete(a, b);
        referee.apply(&u);
        producer.ingest(u);
    }
    producer.flush();
    let forest = queries.connected_components();
    assert!(
        Referee::same_partition(&forest.component, &referee.component_map()),
        "sparse scenario: post-deletion partition mismatch"
    );
    let m = session.metrics();
    println!(
        "[sparse] deleted all {tdeg} edges of vertex {target}: {} demotions, \
         {} exact / {} sketched vertices, {} exact-delta wire bytes, \
         {} dropped — MATCH",
        m.demotions, m.vertices_exact, m.vertices_sketched, m.exact_bytes, m.batches_dropped,
    );
    assert!(m.demotions > 0, "the stripped target must demote");
    assert!(
        m.vertices_exact >= 1,
        "the demoted target must sit in the exact tier"
    );
    assert_eq!(m.batches_dropped, 0, "sparse scenario dropped batches");
    Ok(())
}

/// The deterministic spill workload shared by the recovery parent and
/// its aborting child: a dynamified Erdős–Rényi stream plus the spill
/// session shape (vertex count and resident budget).  Both processes
/// must compute identical values for the replay to line up.
fn recovery_workload() -> (Vec<Update>, u64, u64) {
    use landscape::sketch::params::DEFAULT_COLUMNS;
    use landscape::stream::dynamify::Dynamify;
    use landscape::stream::erdos::ErdosRenyi;
    let v = 1u64 << 11;
    let stream: Vec<Update> = Dynamify::new(ErdosRenyi::new(v, 0.01, 4242), 3).collect();
    // ~64 resident blocks: far fewer than the stream touches, so the
    // crash leaves state split across segments, gutter, and WAL tail
    let params = landscape::SketchParams::with_columns(v, DEFAULT_COLUMNS);
    let budget = 64 * (8 + params.words() as u64 * 8);
    (stream, v, budget)
}

fn recovery_builder(v: u64, dir: &std::path::Path, budget: u64) -> landscape::LandscapeBuilder {
    Landscape::builder()
        .vertices(v)
        .alpha(1)
        .distributor_threads(2)
        .update_log_capacity(32)
        .storage_dir(dir)
        .resident_budget_bytes(budget)
}

/// The crash-recovery scenario (CI-sized), parent side: pick a random
/// durable point `d` and crash point `c`, spawn the child to ingest
/// `stream[..c]` (durably marking only at `d`) and `abort()`, then
/// recover, ingest `stream[c..]`, and check against the exact referee.
fn stage_recovery() -> anyhow::Result<()> {
    let (stream, v, budget) = recovery_workload();
    let mut referee = Referee::new(v);
    for u in &stream {
        referee.apply(u);
    }
    let dir = std::env::temp_dir().join(format!("landscape-e2e-recovery-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // a fresh kill point every run — the property, not one fixed trace
    let mut rng = Xoshiro256::new(u64::from(std::process::id()) | 1);
    let d = rng.next_below(stream.len() as u64) as usize;
    let c = d + rng.next_below((stream.len() - d + 1) as u64) as usize;

    let sw = Stopwatch::new();
    let status = std::process::Command::new(std::env::current_exe()?)
        .args([
            "--scenario",
            "recovery-child",
            "--dir",
            dir.to_str().expect("temp dir is valid UTF-8"),
            "--durable",
            &d.to_string(),
            "--crash",
            &c.to_string(),
        ])
        .status()?;
    if status.success() {
        anyhow::bail!("recovery child was expected to abort mid-stream, but exited cleanly");
    }

    let session = recovery_builder(v, &dir, budget).recover()?;
    if session.metrics().recoveries != 1 {
        anyhow::bail!("recovered session must meter exactly one recovery");
    }
    let mut producer = session.ingest_handle();
    for u in &stream[c..] {
        producer.ingest(*u);
    }
    producer.flush();
    session.flush();
    let forest = session.query_handle().connected_components();
    let ok = Referee::same_partition(&forest.component, &referee.component_map());
    let m = session.metrics();
    println!(
        "[recovery] child aborted after {c}/{} updates (durable cut at {d}); \
         recovered + replayed the rest in {:.2}s: {} components, {} WAL \
         bytes, {} spilled, {} faults, {} dropped — {}",
        stream.len(),
        sw.elapsed_secs(),
        forest.num_components(),
        m.wal_bytes,
        fmt_bytes(m.spill_bytes_written as f64),
        m.block_faults,
        m.batches_dropped,
        if ok { "MATCH" } else { "MISMATCH" },
    );
    assert!(ok, "recovery scenario: partition mismatch after crash + recovery");
    assert_eq!(m.batches_dropped, 0, "recovery scenario dropped batches");
    assert!(m.wal_bytes > 0, "spill mode must have logged to the WAL");
    assert!(
        m.resident_sketch_bytes <= budget,
        "resident gauge {} exceeds the budget {budget}",
        m.resident_sketch_bytes
    );
    drop(session);
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

/// The crash-recovery scenario, child side: ingest to the durable
/// point, `flush()` (checkpoint + fsync'd cut marker), keep going to
/// the crash point so the tail lives only in the WAL and evicted
/// segments, then die for real — no destructors, no final checkpoint.
fn stage_recovery_child() -> anyhow::Result<()> {
    let (stream, v, budget) = recovery_workload();
    let dir = std::path::PathBuf::from(
        flag_value("dir").ok_or_else(|| anyhow::anyhow!("recovery-child needs --dir"))?,
    );
    let d: usize = flag_value("durable")
        .ok_or_else(|| anyhow::anyhow!("recovery-child needs --durable"))?
        .parse()?;
    let c: usize = flag_value("crash")
        .ok_or_else(|| anyhow::anyhow!("recovery-child needs --crash"))?
        .parse()?;

    let session = recovery_builder(v, &dir, budget).build()?;
    let mut producer = session.ingest_handle();
    for u in &stream[..d] {
        producer.ingest(*u);
    }
    producer.flush();
    session.flush(); // the durable cut
    for u in &stream[d..c] {
        producer.ingest(*u);
    }
    producer.flush();
    // settle the tail so it is merged and WAL-logged — but deliberately
    // take no durable mark, leaving exactly what a crash leaves
    let cut = session.cut();
    session.wait_for(cut);
    std::process::abort();
}

/// The multi-tenant serving scenario (CI-sized): three logical graphs
/// over ONE shared fabric — shared distributor pool, two real TCP
/// worker servers — driven entirely through the length-prefixed TCP
/// front end.  The hot tenant saturates its admission quota (every
/// refusal metered and answered with a retry hint, refused chunks
/// withheld, nothing silently dropped); two background tenants stream
/// unthrottled; a fourth, idle tenant is probed for snapshot
/// promptness throughout; every streaming tenant's final partition
/// must match its own exact referee; and each tenant's attributed
/// wire bytes (TBATCH2 out + TDELTA2 back) must stay under the
/// Theorem 5.2 bound computed from that tenant's OWN stream bytes.
fn stage_tenants() -> anyhow::Result<()> {
    use landscape::serve::front::{Client, Front};
    use landscape::serve::wire::WireMetrics;
    use landscape::serve::{Fabric, FabricConfig};
    use landscape::stream::dynamify::Dynamify;
    use landscape::stream::erdos::ErdosRenyi;
    use landscape::worker::remote::WorkerServer;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    struct TenantRun {
        name: &'static str,
        updates: u64,
        rejections: u64,
        ok: bool,
        m: WireMetrics,
    }

    // Same density recipe as the remote scenario: per-vertex leaves
    // clear the γ-flush threshold at V=1024, so every tenant's batches
    // really cross the worker wire and the per-tenant byte meters see
    // real traffic.
    let v = 1u64 << 10;

    let w0 = WorkerServer::bind("127.0.0.1:0")?;
    let w1 = WorkerServer::bind("127.0.0.1:0")?;
    let addrs = vec![w0.local_addr()?.to_string(), w1.local_addr()?.to_string()];
    let w0_thread = std::thread::spawn(move || w0.serve(1));
    let w1_thread = std::thread::spawn(move || w1.serve(1));

    let mut fc = FabricConfig::for_vertices(v);
    fc.base.alpha = 1;
    fc.base.distributor_threads = 2;
    fc.base.remote_window = 8;
    fc.base.worker = WorkerKind::Remote { addrs };
    // Theorem 5.2, attributed per tenant: TBATCH2 + TDELTA2 bytes for
    // tenant t stay under (3 + 1/(γα)) · (t's stream bytes)
    let bound_factor = 3.0 + 1.0 / (fc.base.gamma * fc.base.alpha as f64);
    let fabric = Arc::new(Fabric::spawn(fc).map_err(|e| anyhow::anyhow!("fabric: {e}"))?);

    let front = Front::bind("127.0.0.1:0", Arc::clone(&fabric))?;
    let addr = front.local_addr()?.to_string();
    // four connections: one probe + three streaming tenants
    let front_thread = std::thread::spawn(move || front.serve(4));

    // The idle tenant: an 8-cycle, published and settled before the
    // streamers start — its snapshot latency is the promptness signal.
    let mut probe = Client::connect(&addr)?;
    let idle = probe.create("idle", v, 0, 0)?;
    let cycle: Vec<Update> = (0..8u32).map(|i| Update::insert(i, (i + 1) % 8)).collect();
    anyhow::ensure!(probe.ingest(idle, &cycle)?.is_none(), "idle tenant throttled");
    probe.flush(idle)?;
    let idle_components = (v as usize - 8) + 1;

    let hot_done = AtomicBool::new(false);
    let sw = Stopwatch::new();

    // One streaming tenant, driven over its own TCP connection: ingest
    // in chunks (retrying throttled chunks after the server's hint),
    // flush, query, read the metrics block, say goodbye.
    let run_stream = |name: &'static str,
                      seed: u64,
                      quota: Option<(u64, u64)>|
     -> anyhow::Result<TenantRun> {
        let mut client = Client::connect(&addr)?;
        let (rate, burst) = quota.unwrap_or((0, 0));
        let id = client.create(name, v, rate, burst)?;
        let mut referee = Referee::new(v);
        let mut rejections = 0u64;
        let mut updates = 0u64;
        let mut chunk: Vec<Update> = Vec::with_capacity(1024);
        for u in Dynamify::new(ErdosRenyi::new(v, 0.1, seed), 3) {
            referee.apply(&u);
            chunk.push(u);
            updates += 1;
            if chunk.len() == 1024 {
                loop {
                    match client.ingest(id, &chunk)? {
                        None => break,
                        Some(backoff) => {
                            anyhow::ensure!(
                                quota.is_some(),
                                "unthrottled tenant {name} was refused"
                            );
                            rejections += 1;
                            std::thread::sleep(backoff.min(Duration::from_millis(50)));
                        }
                    }
                }
                chunk.clear();
            }
        }
        while !chunk.is_empty() {
            match client.ingest(id, &chunk)? {
                None => chunk.clear(),
                Some(backoff) => {
                    rejections += 1;
                    std::thread::sleep(backoff.min(Duration::from_millis(50)));
                }
            }
        }
        if quota.is_some() {
            hot_done.store(true, Ordering::Release);
        }
        client.flush(id)?;
        let (_, got) = client.components(id)?;
        let m = client.metrics(id)?;
        client.bye()?;
        Ok(TenantRun {
            name,
            updates,
            rejections,
            ok: Referee::same_partition(&got, &referee.component_map()),
            m,
        })
    };

    let (runs, max_probe, probes) = std::thread::scope(
        |scope| -> anyhow::Result<(Vec<TenantRun>, Duration, u32)> {
            let bg1 = scope.spawn(|| run_stream("bg-even", 9091, None));
            let bg2 = scope.spawn(|| run_stream("bg-odd", 9092, None));
            let hot = scope.spawn(|| run_stream("hot", 9093, Some((200_000, 10_000))));

            // Promptness under a saturating neighbor: the idle tenant's
            // snapshot is bounded by its OWN in-flight work (none), not
            // by the hot tenant's backlog on the shared pipeline.
            let mut max_probe = Duration::ZERO;
            let mut probes = 0u32;
            loop {
                let t0 = Instant::now();
                let (nc, map) = probe.components(idle)?;
                max_probe = max_probe.max(t0.elapsed());
                probes += 1;
                anyhow::ensure!(
                    nc as usize == idle_components && map.len() == v as usize,
                    "idle tenant's answer drifted under load: {nc} components"
                );
                if hot_done.load(Ordering::Acquire) || probes >= 64 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(5));
            }

            let mut runs = Vec::new();
            for h in [hot, bg1, bg2] {
                runs.push(
                    h.join()
                        .map_err(|_| anyhow::anyhow!("tenant thread panicked"))??,
                );
            }
            Ok((runs, max_probe, probes))
        },
    )?;
    let secs = sw.elapsed_secs();

    let total: u64 = runs.iter().map(|r| r.updates).sum();
    let ratios: Vec<String> = runs
        .iter()
        .map(|r| {
            let net = r.m.batch_bytes_sent + r.m.delta_bytes_received;
            format!("{}={:.2}×", r.name, net as f64 / r.m.stream_bytes as f64)
        })
        .collect();
    println!(
        "[tenants] 3 streaming tenants + 1 idle over one fabric via the TCP \
         front: {} updates in {:.2}s ({}); hot tenant {} metered quota \
         rejections; idle probe max {:?} over {} probes; per-tenant \
         wire/stream ratios (bound {:.0}×): {}",
        total,
        secs,
        fmt_rate(total as f64 / secs),
        runs[0].rejections,
        max_probe,
        probes,
        bound_factor,
        ratios.join(", "),
    );

    for r in &runs {
        assert!(r.ok, "tenant {} diverges from its own referee", r.name);
        assert_eq!(
            r.m.updates_ingested, r.updates,
            "tenant {}: every admitted update ingested",
            r.name
        );
        assert_eq!(
            r.m.stream_bytes,
            r.updates * 9,
            "tenant {}: stream-byte accounting",
            r.name
        );
        assert_eq!(r.m.batches_dropped, 0, "tenant {} dropped batches", r.name);
        assert!(
            r.m.batch_bytes_sent > 0,
            "tenant {}: no batches crossed the wire",
            r.name
        );
        let net = r.m.batch_bytes_sent + r.m.delta_bytes_received;
        assert!(
            (net as f64) < bound_factor * r.m.stream_bytes as f64,
            "tenant {}: per-tenant Theorem 5.2 bound violated ({} wire bytes \
             vs {} stream bytes)",
            r.name,
            net,
            r.m.stream_bytes
        );
        assert_eq!(
            r.m.quota_rejections, r.rejections,
            "tenant {}: rejection meter disagrees with the client",
            r.name
        );
    }
    assert!(runs[0].rejections > 0, "the hot tenant was never throttled");
    assert!(
        runs[1].rejections == 0 && runs[2].rejections == 0,
        "a background tenant was throttled"
    );
    let bound = Duration::from_secs(10);
    assert!(
        max_probe < bound,
        "idle tenant's snapshot took {max_probe:?} under a hot neighbor"
    );

    probe.bye()?;
    let _ = front_thread.join();
    drop(fabric); // closes the worker connections so the servers exit
    let _ = w0_thread.join();
    let _ = w1_thread.join();
    Ok(())
}

/// The value following `--<name>`, if any.
fn flag_value(name: &str) -> Option<String> {
    let flag = format!("--{name}");
    let mut args = std::env::args();
    while let Some(a) = args.next() {
        if a == flag {
            return args.next();
        }
    }
    None
}

/// The value following `--scenario`, if any.
fn scenario_arg() -> Option<String> {
    flag_value("scenario")
}

fn main() -> anyhow::Result<()> {
    match scenario_arg().as_deref() {
        Some("query") => return stage0_query_tiers(),
        Some("remote") => return stage_remote(),
        Some("snapshot") => return stage_snapshot(),
        Some("sparse") => return stage_sparse(),
        Some("recovery") => return stage_recovery(),
        Some("recovery-child") => return stage_recovery_child(),
        Some("tenants") => return stage_tenants(),
        Some(other) => {
            anyhow::bail!(
                "unknown scenario {other} (query|remote|snapshot|sparse|recovery|tenants)"
            )
        }
        None => {}
    }

    stage0_query_tiers()?;
    stage_snapshot()?;
    stage_sparse()?;
    stage1_xla()?;

    // ---- stage 2: full run, native + remote TCP workers ----
    let d = datasets::by_name("kron12").unwrap();
    let v = d.model.num_vertices();

    // a real worker process-equivalent: TCP server on loopback
    let server = landscape::worker::remote::WorkerServer::bind("127.0.0.1:0")?;
    let addr = server.local_addr()?.to_string();
    let server_thread = std::thread::spawn(move || server.serve(1));

    let mut cfg = CoordinatorConfig::for_vertices(v);
    cfg.distributor_threads = 2; // slot 0 native, slot 1 remote? — mixed below
    cfg.worker = WorkerKind::Native;
    let session = Landscape::from_config(cfg)?;
    let mut producer = session.ingest_handle();
    let queries = session.query_handle();

    // one extra distributor-equivalent: drive the remote worker directly
    // with a few batches to prove the wire path with identical results
    {
        use landscape::worker::remote::RemoteWorker;
        use landscape::worker::{NativeWorker, WorkerBackend, WorkerSeeds};
        let params = *session.params();
        let remote = RemoteWorker::connect(&addr, params, session.config().graph_seed, 1)?;
        let native = NativeWorker::new(WorkerSeeds::derive(
            params,
            session.config().graph_seed,
            1,
        ));
        let others: Vec<u32> = (1..400).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        remote.process(0, &others, &mut a)?;
        native.process(0, &others, &mut b)?;
        assert_eq!(a, b, "remote TCP delta != native delta");
        remote.shutdown();
        println!(
            "[stage 2] remote TCP worker at {addr}: delta bit-identical to \
             native ({} sent / {} received)",
            fmt_bytes(remote.bytes_sent.load(std::sync::atomic::Ordering::Relaxed) as f64),
            fmt_bytes(
                remote
                    .bytes_received
                    .load(std::sync::atomic::Ordering::Relaxed) as f64
            ),
        );
    }
    let _ = server_thread.join();

    // the main ingest run, with a referee shadowing every update
    let mut referee = Referee::new(v);
    let stream = d.stream();
    println!(
        "[stage 2] ingesting kron12: V={v}, ~{} updates, sketch {}",
        stream.len_hint().unwrap_or(0),
        fmt_bytes(session.sketch_bytes() as f64)
    );
    let sw = Stopwatch::new();
    let mut n = 0u64;
    let mut rng = Xoshiro256::new(17);
    let mut query_log: Vec<(String, f64)> = Vec::new();
    for u in stream {
        referee.apply(&u);
        producer.ingest(u);
        n += 1;
        // ---- stage 3: queries during the stream ----
        if n % 6_000_000 == 0 {
            producer.flush(); // publish the prefix the queries measure
            let qsw = Stopwatch::new();
            let forest = queries.full_connectivity_query();
            query_log.push(("full-boruvka".into(), qsw.elapsed_secs()));
            let qsw = Stopwatch::new();
            let _ = queries.connected_components();
            query_log.push(("greedy-global".into(), qsw.elapsed_secs()));
            let pairs: Vec<(u32, u32)> = (0..128)
                .map(|_| (rng.next_below(v) as u32, rng.next_below(v) as u32))
                .collect();
            let qsw = Stopwatch::new();
            let _ = queries.reachability(&pairs);
            query_log.push(("greedy-reach-128".into(), qsw.elapsed_secs()));
            let _ = forest;
        }
    }
    producer.flush();
    session.flush(); // count until every update reaches the sketches
    let ingest_secs = sw.elapsed_secs();
    println!(
        "[stage 2] {} updates in {:.1}s ({})",
        n,
        ingest_secs,
        fmt_rate(n as f64 / ingest_secs)
    );
    for (kind, secs) in &query_log {
        println!("[stage 3] query {kind}: {secs:.6}s");
    }

    // ---- stage 4: final query + exact correctness check ----
    let qsw = Stopwatch::new();
    let forest = queries.full_connectivity_query();
    let final_query = qsw.elapsed_secs();
    let exact = referee.component_map();
    let ok = Referee::same_partition(&forest.component, &exact);
    println!(
        "[stage 4] final query {:.3}s: {} components (exact: {}) — {}",
        final_query,
        forest.num_components(),
        {
            let mut roots = exact.clone();
            roots.sort_unstable();
            roots.dedup();
            roots.len()
        },
        if ok { "MATCH" } else { "MISMATCH" }
    );

    let m = session.metrics();
    println!(
        "[report] rate {} | comm {:.2}x stream | {} batches | {} local updates \
         | sketch {} | {} full / {} greedy queries",
        fmt_rate(n as f64 / ingest_secs),
        m.communication_factor(),
        m.batches_sent,
        m.updates_local,
        fmt_bytes(session.sketch_bytes() as f64),
        m.queries_full,
        m.queries_greedy,
    );
    assert_eq!(
        m.batches_dropped, 0,
        "batches silently dropped during the run"
    );
    assert!(ok, "correctness check failed");
    Ok(())
}
