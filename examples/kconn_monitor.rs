//! Network-resilience monitoring with k-edge-connectivity — the paper's
//! min-cut application (network reliability, §1).
//!
//! An infrastructure-like backbone (grid + shortcuts) degrades as links
//! fail and recover; the operator asks "is the network still
//! 3-edge-connected?" after each wave of failures.  Landscape maintains
//! k=3 independent connectivity sketches and answers via certificates
//! (Theorem 5.4) — detecting exactly when the min cut drops below 3.
//!
//! ```bash
//! cargo run --release --offline --example kconn_monitor
//! ```

use landscape::session::{IngestHandle, QueryHandle};
use landscape::Landscape;
use landscape::stream::realworld::GridLike;
use landscape::stream::{edge_list, Update};
use landscape::util::rng::Xoshiro256;
use landscape::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let nodes = 1024u64;
    let k = 3u32;
    // a redundant backbone: dense local mesh + long-range shortcuts
    let base = GridLike::new(nodes, 0.95, 6.0, 11);
    let edges = edge_list(&base);

    let session = Landscape::builder().vertices(nodes).k(k).alpha(1).build()?;
    let mut ingest = session.ingest_handle();
    let queries = session.query_handle();
    println!(
        "monitoring {} links across {nodes} nodes with k={k} sketches ({})",
        edges.len(),
        landscape::benchkit::fmt_bytes(session.sketch_bytes() as f64)
    );

    for &(a, b) in &edges {
        ingest.ingest(Update::insert(a, b));
    }
    report(&mut ingest, &queries, k, "baseline");

    let mut rng = Xoshiro256::new(5);
    let mut down: Vec<(u32, u32)> = Vec::new();
    for wave in 1..=4 {
        // a wave of correlated link failures (random 8% of live links)
        let mut failed = 0;
        for &(a, b) in &edges {
            if !down.contains(&(a, b)) && rng.next_bool(0.08) {
                ingest.ingest(Update::delete(a, b));
                down.push((a, b));
                failed += 1;
            }
        }
        println!("wave {wave}: {failed} links failed ({} total down)", down.len());
        report(&mut ingest, &queries, k, &format!("after wave {wave}"));

        // repairs: half of the downed links come back
        let repair = down.len() / 2;
        for _ in 0..repair {
            let i = rng.next_below(down.len() as u64) as usize;
            let (a, b) = down.swap_remove(i);
            ingest.ingest(Update::insert(a, b));
        }
        println!("        {repair} links repaired");
    }

    report(&mut ingest, &queries, k, "final");
    Ok(())
}

fn report(ingest: &mut IngestHandle, queries: &QueryHandle, k: u32, label: &str) {
    ingest.flush(); // publish this producer's tail before querying
    let sw = Stopwatch::new();
    let cut = queries.k_connectivity();
    match cut {
        Some(w) => println!(
            "  [{label}] RESILIENCE ALERT: min cut = {w} (< {k}) — {:.3}s",
            sw.elapsed_secs()
        ),
        None => println!(
            "  [{label}] healthy: at least {k}-edge-connected — {:.3}s",
            sw.elapsed_secs()
        ),
    }
}
