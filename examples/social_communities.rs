//! Tracking communities in a churning social network — the dynamic-graph
//! use case from the paper's introduction (friend add/remove streams,
//! community = connected component).
//!
//! A power-law "social" graph takes continuous edge churn; after every
//! epoch the app asks for the community structure and for reachability
//! between user pairs.  GreedyCC answers the cheap queries; deletions of
//! spanning-forest edges dirty their communities, and the next query
//! resolves just those via the partial (warm-started Borůvka) tier.
//!
//! ```bash
//! cargo run --release --offline --example social_communities
//! ```

use landscape::Landscape;
use landscape::stream::realworld::ChungLu;
use landscape::stream::{EdgeModel, Update};
use landscape::util::rng::Xoshiro256;
use landscape::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let users = 20_000u64;
    let base = ChungLu::new(users, 0.5, 120_000, 7);
    let session = Landscape::builder().vertices(users).build()?;
    let mut ingest = session.ingest_handle();
    let queries = session.query_handle();
    let mut rng = Xoshiro256::new(99);

    // Phase 1: the initial friendship graph arrives as a stream.
    let sw = Stopwatch::new();
    let mut live: Vec<(u32, u32)> = Vec::new();
    for a in 0..users as u32 {
        for b in (a + 1)..(users as u32).min(a + 2000) {
            if base.contains(a, b) {
                ingest.ingest(Update::insert(a, b));
                live.push((a, b));
            }
        }
    }
    println!(
        "bootstrapped {} friendships in {:.2}s",
        live.len(),
        sw.elapsed_secs()
    );

    // Phase 2: churn epochs — friendships break and form.
    for epoch in 0..5 {
        let churn = live.len() / 20;
        for _ in 0..churn {
            // remove a random existing friendship
            let i = rng.next_below(live.len() as u64) as usize;
            let (a, b) = live.swap_remove(i);
            ingest.ingest(Update::delete(a, b));
            // ... and form a new random one
            loop {
                let x = rng.next_below(users) as u32;
                let y = rng.next_below(users) as u32;
                if x != y
                    && !live.contains(&(x.min(y), x.max(y)))
                    && !base.contains(x.min(y), x.max(y))
                {
                    ingest.ingest(Update::insert(x, y));
                    live.push((x.min(y), x.max(y)));
                    break;
                }
            }
        }

        // community query at the end of the epoch: publish this
        // producer's tail, then query through the read-side handle
        ingest.flush();
        let qsw = Stopwatch::new();
        let forest = queries.connected_components();
        let communities = forest.num_components();
        let q1 = qsw.elapsed_secs();

        // reachability between random user pairs (friend suggestions)
        let pairs: Vec<(u32, u32)> = (0..1000)
            .map(|_| {
                (
                    rng.next_below(users) as u32,
                    rng.next_below(users) as u32,
                )
            })
            .collect();
        let qsw = Stopwatch::new();
        let reach = queries.reachability(&pairs);
        let connected = reach.iter().filter(|&&r| r).count();
        println!(
            "epoch {epoch}: {churn} churns, {communities} communities \
             (query {:.4}s), {connected}/1000 pairs reachable ({:.6}s)",
            q1,
            qsw.elapsed_secs()
        );
    }

    let m = session.metrics();
    println!(
        "totals: {} updates, {} full / {} partial / {} GreedyCC-served \
         queries, {} communities dirtied",
        m.updates_ingested,
        m.queries_full,
        m.queries_partial,
        m.queries_greedy,
        m.dirty_components
    );
    Ok(())
}
