# Convenience targets referenced by docs and test skip messages.

.PHONY: build test storage-test fixtures artifacts fmt clippy lint miri tsan ci

build:
	cargo build --release --workspace

test:
	cargo test -q --workspace

# The external-memory storage tier: WAL/spill unit tests plus the
# crash-recovery integration suite (see docs/STORAGE.md).
storage-test:
	cargo test -q -p landscape --lib storage::
	cargo test -q -p landscape --test storage_recovery

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

# Invariant lint pass over rust/src (see docs/INVARIANTS.md).
lint:
	cargo run --release -p landscape --bin landscape_lint

# Interpreter pass over the unsafe/atomic core.  Requires
# `rustup +nightly component add miri`.  The filter matches CI and
# deliberately excludes the arena double-recycle test (forged-alias UB).
miri:
	MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test -p landscape --lib sketch:: work_queue

# Best-effort data-race pass; requires nightly + rust-src.
tsan:
	RUSTFLAGS=-Zsanitizer=thread cargo +nightly test -Z build-std --target x86_64-unknown-linux-gnu -p landscape --test concurrent_ingest

ci: fmt clippy lint build test
	python -m pytest python/tests -q

# Cross-language golden fixtures (pure numpy; no jax needed).
fixtures:
	cd python && python3 gen_fixtures.py

# AOT-compiled HLO kernels for the `xla` feature (needs jax).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
