# Convenience targets referenced by docs and test skip messages.

.PHONY: build test fixtures artifacts fmt clippy ci

build:
	cargo build --release --workspace

test:
	cargo test -q --workspace

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

ci: fmt clippy build test
	python -m pytest python/tests -q

# Cross-language golden fixtures (pure numpy; no jax needed).
fixtures:
	cd python && python3 gen_fixtures.py

# AOT-compiled HLO kernels for the `xla` feature (needs jax).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
